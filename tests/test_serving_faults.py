"""Fault-injection suite (docs/DESIGN.md §9, run as a dedicated CI step).

The §9 recovery contracts, proven rather than asserted:
  * an engine-call failure retries once on the vmap semantics-of-record
    engine and the answers are still exact; a second failure rejects only
    the affected requests — the service keeps serving;
  * a compaction crashing mid-swap leaves the manifest on the pre-swap
    epoch, pinned readers keep answering identically, and a retried
    compaction completes;
  * a failing snapshot store surfaces as the injected error, never a
    half-loaded index;
  * no injected fault can make the service return *wrong* (rather than
    rejected) answers.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api
from repro.api import SearchRequest
from repro.core import derive_params
from repro.serving import (Answer, COMPACTION_SWAP, ENGINE_CALL, FaultPlan,
                           InjectedFault, Rejected, SNAPSHOT_LOAD,
                           ServingRuntime)
from repro.streaming import StreamingDETLSH
from tests.conftest import brute_force_knn, make_clustered, make_queries_near

D = 16
SAT = dict(r_min=1e6, M=10**6)


def _runtime(rng, n=512, **kw):
    p = derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    idx = StreamingDETLSH.build(
        jnp.asarray(make_clustered(rng, n, D)), jax.random.key(0), p,
        Nr=32, leaf_size=16, delta_capacity=32, max_segments=3)
    plan = FaultPlan()
    kw = {**dict(max_batch=8, pad_to=8), **kw}
    rt = ServingRuntime(idx, k=5, fault_plan=plan,
                        request=SearchRequest(k=5, **SAT), **kw)
    return rt, idx, plan


def _serve_and_check(rt, idx, queries):
    """Serve and assert every answer is the exact brute-force top-k over
    the current survivors — the 'no fault can produce wrong answers'
    oracle.  Survivor rows are mapped through their global ids (mutations
    renumber rows, answers are in gid space)."""
    data, gids = idx.pin_state().survivors()
    out = rt.serve([(time.perf_counter(), q) for q in queries])
    gt_i, gt_d = brute_force_knn(data, queries, rt.k)
    for i, o in enumerate(out):
        if isinstance(o, Rejected):
            continue
        assert set(o.ids.tolist()) == set(gids[gt_i[i]].tolist()), i
        np.testing.assert_allclose(o.dists, gt_d[i], rtol=1e-4, atol=1e-4)
    return out


# ---------------------------------------------------------------------------
# FaultPlan mechanics
# ---------------------------------------------------------------------------

def test_fault_plan_arms_fires_and_counts():
    plan = FaultPlan()
    plan.fire(ENGINE_CALL)                       # unarmed: counted, no raise
    assert plan.fired[ENGINE_CALL] == 1 and plan.raised[ENGINE_CALL] == 0
    plan.arm(ENGINE_CALL, times=2)
    assert plan.armed(ENGINE_CALL) == 2
    for _ in range(2):
        with pytest.raises(InjectedFault) as e:
            plan.fire(ENGINE_CALL, detail="boom")
        assert e.value.site == ENGINE_CALL and "boom" in str(e.value)
    plan.fire(ENGINE_CALL)                       # charges consumed
    assert plan.fired[ENGINE_CALL] == 4 and plan.raised[ENGINE_CALL] == 2
    with pytest.raises(ValueError, match="unknown fault site"):
        plan.arm("not_a_site")
    with pytest.raises(ValueError):
        plan.arm(ENGINE_CALL, times=0)


def test_fault_plan_custom_exception_type():
    plan = FaultPlan().arm(COMPACTION_SWAP, exc=OSError)
    with pytest.raises(OSError, match="injected fault at compaction_swap"):
        plan.fire(COMPACTION_SWAP)


def test_fault_plan_unknown_site_names_valid_set():
    """A typo'd site must fail loudly at arm() time, naming the valid
    sites — not silently never fire (docstring contract)."""
    from repro.serving import faults
    plan = FaultPlan()
    with pytest.raises(ValueError) as e:
        plan.arm("wal_apend")                    # the classic typo
    for site in faults.SITES:
        assert site in str(e.value)
    with pytest.raises(ValueError, match="unknown fault site"):
        plan.fire("wal_apend")
    with pytest.raises(ValueError, match="unknown fault site"):
        plan.armed("wal_apend")


def test_fault_plan_durability_sites_registered():
    from repro.serving import faults
    from repro.serving import (CHECKPOINT_INSTALL, SNAPSHOT_WRITE,
                               WAL_APPEND, WAL_FSYNC)
    assert {WAL_APPEND, WAL_FSYNC, SNAPSHOT_WRITE,
            CHECKPOINT_INSTALL} <= set(faults.SITES)


def test_fault_plan_skip_defers_armed_charges():
    """skip=k lets the first k crossings through unharmed, so a test can
    target the (k+1)-th crossing of a nested site (e.g. the *commit*
    crossing of CHECKPOINT_INSTALL)."""
    from repro.serving import WAL_APPEND
    plan = FaultPlan().arm(WAL_APPEND, times=1, skip=2)
    plan.fire(WAL_APPEND)                        # skipped
    plan.fire(WAL_APPEND)                        # skipped
    with pytest.raises(InjectedFault):
        plan.fire(WAL_APPEND)                    # the targeted crossing
    plan.fire(WAL_APPEND)                        # charges consumed
    assert plan.fired[WAL_APPEND] == 4 and plan.raised[WAL_APPEND] == 1
    with pytest.raises(ValueError, match="skip must be"):
        plan.arm(WAL_APPEND, skip=-1)


# ---------------------------------------------------------------------------
# Engine-call failures
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_engine_failure_retries_on_vmap_with_exact_answers(rng):
    rt, idx, plan = _runtime(rng)
    data, _ = idx.pin_state().survivors()
    queries = make_queries_near(data, rng, 6)
    plan.arm(ENGINE_CALL, times=1)
    out = _serve_and_check(rt, idx, queries)
    assert all(isinstance(o, Answer) for o in out)
    assert rt.stats.retries == 1
    assert plan.raised[ENGINE_CALL] == 1 and rt.stats.shed_total == 0


@pytest.mark.timeout(300)
def test_persistent_engine_failure_rejects_only_affected_batch(rng):
    rt, idx, plan = _runtime(rng, max_batch=4)
    data, _ = idx.pin_state().survivors()
    queries = make_queries_near(data, rng, 8)    # two batches of 4
    plan.arm(ENGINE_CALL, times=2)               # first batch + its retry
    out = _serve_and_check(rt, idx, queries)
    rejected = [o for o in out if isinstance(o, Rejected)]
    answered = [o for o in out if isinstance(o, Answer)]
    assert len(rejected) == 4 and len(answered) == 4
    assert all(o.reason == "engine_failure" for o in rejected)
    assert rt.stats.shed["engine_failure"] == 4
    # epochs drained even through the failure path (finally-released)
    assert idx.manifest.pinned_versions() == ()
    # the service keeps serving afterwards
    out2 = _serve_and_check(rt, idx, queries[:3])
    assert all(isinstance(o, Answer) for o in out2)


# ---------------------------------------------------------------------------
# Compaction crash mid-swap
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_compaction_crash_recovers_to_pre_swap_epoch(rng):
    rt, idx, plan = _runtime(rng, n=256)
    rt.upsert(make_clustered(rng, 70, D))        # fan-out + tombstones
    rt.delete(np.arange(0, 20))
    data, _ = idx.pin_state().survivors()
    queries = jnp.asarray(make_queries_near(data, rng, 4))

    epoch = rt.pin()
    before = epoch.search(queries, SearchRequest(k=5, n_active=4, **SAT))
    v0, segs0 = idx.manifest.version, list(idx.manifest.segments)
    plan.arm(COMPACTION_SWAP, times=1)
    assert rt.compact() is False                 # crashed mid-install
    assert rt.stats.compaction_crashes == 1
    assert isinstance(rt.last_compaction_error, InjectedFault)
    # pre-swap epoch fully intact: same version, same segment list
    assert idx.manifest.version == v0
    assert len(idx.manifest.segments) == len(segs0)
    assert all(a is b for a, b in zip(idx.manifest.segments, segs0))
    during = epoch.search(queries, SearchRequest(k=5, n_active=4, **SAT))
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(during.ids))
    # retried compaction completes and the pinned reader still answers
    # identically (RCU: the swap happened underneath it)
    assert rt.compact() is True
    after = epoch.search(queries, SearchRequest(k=5, n_active=4, **SAT))
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))
    np.testing.assert_array_equal(np.asarray(before.dists),
                                  np.asarray(after.dists))
    rt.release(epoch)
    # live queries after the crash+recovery are exact too
    _serve_and_check(rt, idx, np.asarray(queries))


@pytest.mark.timeout(300)
def test_compaction_crash_during_upsert_trigger_keeps_serving(rng):
    """maybe_compact firing inside the upsert path crashes: the upsert
    itself must stand (rows inserted), the crash is counted, and a later
    compaction succeeds."""
    rt, idx, plan = _runtime(rng, n=256)
    plan.arm(COMPACTION_SWAP, times=1)
    # enough seals to cross max_segments and trigger compaction
    rt.upsert(make_clustered(rng, 140, D))
    assert rt.stats.compaction_crashes == 1
    assert idx.n_live == 256 + 140               # upsert survived the crash
    data, _ = idx.pin_state().survivors()
    _serve_and_check(rt, idx, make_queries_near(data, rng, 5))
    assert rt.compact() is True                  # recovery compaction


# ---------------------------------------------------------------------------
# Snapshot-load boundary
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_snapshot_load_fault_surfaces_not_half_loads(rng, tmp_path):
    p = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
    idx = StreamingDETLSH.build(
        jnp.asarray(make_clustered(rng, 64, 8)), jax.random.key(0), p,
        Nr=8, leaf_size=8, delta_capacity=16, max_segments=2)
    idx.save(tmp_path / "snap")
    plan = FaultPlan().arm(SNAPSHOT_LOAD, times=1)
    with plan.installed_on_load():
        with pytest.raises(InjectedFault) as e:
            repro.api.load(str(tmp_path / "snap"))
        assert e.value.site == SNAPSHOT_LOAD
        assert "snap" in e.value.detail          # names the offending path
        # charge consumed: the next load succeeds and still counts fires
        reloaded = repro.api.load(str(tmp_path / "snap"))
    assert plan.fired[SNAPSHOT_LOAD] == 2
    assert reloaded.n_live == idx.n_live
    # the hook uninstalled cleanly on context exit
    from repro.api import persist
    assert persist.load_fault_hook is None
