"""The decode subsystem (docs/DESIGN.md §10): KVCacheIndex + LSHDecoder.

Covers the three load-bearing claims:

  * the MIPS -> L2 reduction is order-preserving for arbitrary key norms,
    and query rescaling never changes the ranking (hypothesis properties);
  * the fused-engine KV retrieval is the *same algorithm* as the seed
    ``core.det_attention`` path — identical forests from identical inputs,
    and (forced single-round) the retrieved set is exactly the top-m of an
    exact scan under the same augmentation;
  * the mutable-index surface behaves: upserts land in the delta and
    survive a seal, deletes tombstone, the protocol shapes hold.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import MutableAnnIndex, SearchRequest, as_ann_index
from repro.decode import (KVCacheIndex, KVSpec, LSHDecoder,
                          augment_keys, augment_queries, mips_radius,
                          sparse_decode_attention)
from repro.decode.mips import normalize_queries

_shim = pytest.mark.filterwarnings(
    "ignore:.*is deprecated. use.*:DeprecationWarning")


def _cache(rng, b=1, S=256, hk=2, dh=16, scale=0.3):
    return jnp.asarray(rng.standard_normal((b, S, hk, dh))
                       .astype(np.float32) * scale)


def _query_at(k_cache, pos, g, scale=8.0):
    """Decode query aligned with the key at ``pos`` (strong attention)."""
    b, _, hk, dh = k_cache.shape
    q = np.repeat(np.asarray(k_cache[:, pos])[:, :, None, :], g, axis=2)
    return jnp.asarray((q * scale).reshape(b, 1, hk * g, dh))


# ----------------------------------------------------------------------
# MIPS -> L2 reduction properties
# ----------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 16), st.floats(0.1, 30.0))
def test_mips_augmentation_preserves_ip_order(seed, qscale):
    """argmax q.k == argmin ||q_hat - k_hat|| for keys of *varying* norm
    (the whole point of the lift: plain L2-LSH on raw keys gets this
    wrong) and for any query scale."""
    r = np.random.default_rng(seed)
    keys = (r.standard_normal((48, 6)).astype(np.float32)
            * r.uniform(0.1, 10.0, (48, 1)).astype(np.float32))
    q = r.standard_normal(6).astype(np.float32) * qscale
    R2 = mips_radius(jnp.asarray(keys))
    aug, n_clipped = augment_keys(jnp.asarray(keys), R2)
    assert int(n_clipped) == 0          # radius covers its own keys
    qa = augment_queries(jnp.asarray(q))
    d2 = np.asarray(jnp.sum((aug - qa[None]) ** 2, -1))
    ip = keys @ q
    np.testing.assert_array_equal(np.argsort(d2, kind="stable"), np.argsort(-ip, kind="stable"))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 16), st.floats(0.05, 50.0))
def test_query_normalization_is_order_invariant(seed, qscale):
    """Rescaling a query lane to ||q|| = R changes LSH contrast, never the
    augmented-L2 ranking."""
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.standard_normal((32, 5)).astype(np.float32))
    q = jnp.asarray(r.standard_normal(5).astype(np.float32) * qscale)
    R2 = mips_radius(keys)
    aug, _ = augment_keys(keys, R2)
    qa = augment_queries(q)
    qn = normalize_queries(qa, R2)
    np.testing.assert_allclose(float(jnp.sum(qn ** 2)), float(R2),
                               rtol=1e-4)
    d_raw = np.asarray(jnp.sum((aug - qa[None]) ** 2, -1))
    d_norm = np.asarray(jnp.sum((aug - qn[None]) ** 2, -1))
    np.testing.assert_array_equal(np.argsort(d_raw, kind="stable"), np.argsort(d_norm, kind="stable"))


def test_clipped_keys_are_only_over_admitted(rng):
    """A key whose norm outgrows the frozen R ranks at least as close as
    the exact reduction would rank it — never lost."""
    keys = rng.standard_normal((16, 8)).astype(np.float32)
    R2 = mips_radius(jnp.asarray(keys))
    big = jnp.asarray(keys[:1] * 5.0)              # norm > R
    aug, n_clipped = augment_keys(big, R2)
    assert int(n_clipped) == 1
    q = jnp.asarray(rng.standard_normal(8).astype(np.float32))
    qa = augment_queries(q)
    d2_clipped = float(jnp.sum((aug[0] - qa) ** 2))
    # vs the exact reduction at a radius that actually covers the key:
    # frozen-R clipping can only *shrink* the distance (over-admission)
    R2_true = mips_radius(big)
    d2_exact = float(jnp.sum(q ** 2) + R2_true - 2 * jnp.dot(big[0], q))
    assert d2_clipped <= d2_exact + 1e-3


# ----------------------------------------------------------------------
# Validation
# ----------------------------------------------------------------------

def test_kvspec_validates_through_indexspec():
    with pytest.raises(ValueError, match="Nr"):
        KVSpec(Nr=300)                  # uint8 symbol budget
    with pytest.raises(ValueError, match="leaf_size"):
        KVSpec(leaf_size=0)
    with pytest.raises(ValueError, match="breakpoint_method"):
        KVSpec(breakpoint_method="bogus")
    with pytest.raises(ValueError, match="m_top"):
        KVSpec(m_top=0)
    with pytest.raises(ValueError, match="max_rounds"):
        KVSpec(max_rounds=-1)
    with pytest.raises(ValueError, match="radius_slack"):
        KVSpec(radius_slack=-0.5)


def test_decoder_window_must_cover_refresh_gap(rng):
    idx = KVCacheIndex.prefill(_cache(rng, S=128), jax.random.key(0),
                               KVSpec(delta_capacity=16, m_top=16))
    with pytest.raises(ValueError, match="window"):
        LSHDecoder(idx, window=4, refresh_every=8)


# ----------------------------------------------------------------------
# Oracle: same algorithm as the seed det_attention path
# ----------------------------------------------------------------------

@_shim
def test_forests_bit_identical_to_seed(rng):
    """Same cache + same PRNG key -> the fused-built KV forests equal the
    seed per-tree build structure-for-structure (same projections, same
    augmentation, same full_sort breakpoints)."""
    from repro.core import det_attention as DA
    b, hk = 1, 2
    k_cache = _cache(rng, b=b, S=256, hk=hk, dh=16)
    seed_idx = DA.build_kv_index(k_cache, jax.random.key(7))
    kv = KVCacheIndex.prefill(k_cache, jax.random.key(7), KVSpec())

    np.testing.assert_array_equal(np.asarray(kv.A),
                                  np.asarray(seed_idx.A))
    H = b * hk
    for name in ("point_ids", "leaf_lo", "leaf_hi", "leaf_valid",
                 "breakpoints"):
        ours = np.asarray(getattr(kv.forest, name))
        seed = np.asarray(getattr(seed_idx, name)).reshape(
            (H,) + ours.shape[1:])
        np.testing.assert_array_equal(ours, seed, err_msg=name)


def test_retrieval_matches_exact_scan_on_wide_radius(rng):
    """With a radius wide enough to admit every leaf in round one, the
    fused engine's top-m must be exactly the top-m of a brute-force scan
    under the same (normalized-query) augmentation — the engine changes
    *work*, never the metric."""
    spec = KVSpec(m_top=24, delta_capacity=16)
    k_cache = _cache(rng, S=256, hk=2, dh=16)
    kv = KVCacheIndex.prefill(k_cache, jax.random.key(3), spec)
    g = 2
    q = _query_at(k_cache, 77, g)
    res = kv.retrieve(q, r_min=1e6)
    assert int(np.asarray(res.rounds).max()) == 1

    q_aug = normalize_queries(
        augment_queries(jnp.asarray(np.asarray(q).reshape(
            kv.H, g, kv.dh))), kv.R2[:, None])
    d = np.sqrt((((np.asarray(q_aug)[:, :, None, :]
                   - kv._aug[:, None, :, :]) ** 2).sum(-1)))  # (H, g, n)
    exact = np.argsort(d, axis=-1, kind="stable")[..., :spec.m_top]
    got = np.asarray(res.ids)[..., :spec.m_top]               # forest tier
    for h in range(kv.H):
        for lane in range(g):
            assert set(got[h, lane]) == set(exact[h, lane])


def test_retrieval_finds_planted_position(rng):
    k_cache = _cache(rng, S=512, hk=2, dh=32)
    kv = KVCacheIndex.prefill(k_cache, jax.random.key(0),
                              KVSpec(m_top=32, delta_capacity=32))
    hits = []
    for pos in (17, 123, 400):
        res = kv.retrieve(_query_at(k_cache, pos, g=2))
        hits.append((np.asarray(res.ids) == pos).any(axis=-1).mean())
    assert np.mean(hits) >= 0.75, hits


# ----------------------------------------------------------------------
# Mutation: upsert / seal / delete
# ----------------------------------------------------------------------

def test_upsert_lands_in_delta_and_survives_seal(rng):
    cap = 8
    k_cache = _cache(rng, S=128, hk=2, dh=16)
    kv = KVCacheIndex.prefill(k_cache, jax.random.key(1),
                              KVSpec(delta_capacity=cap, m_top=16))
    new_keys = rng.standard_normal((cap, 1, 2, 16)).astype(np.float32) * 0.3
    probe = jnp.asarray(np.repeat(
        new_keys[3][:, :, None, :], 2, axis=2).reshape(1, 1, 4, 16) * 8.0)

    positions = [kv.upsert(jnp.asarray(new_keys[i])) for i in range(cap - 1)]
    assert positions == list(range(128, 128 + cap - 1))
    assert kv.seals == 0 and kv.delta.count == cap - 1
    res = kv.retrieve(probe)            # delta tier answers pre-seal
    assert (np.asarray(res.ids) == positions[3]).any()

    kv.upsert(jnp.asarray(new_keys[cap - 1]))      # fills -> auto-seal
    assert kv.seals == 1 and kv.delta.count == 0
    assert kv.n_sealed == 128 + cap
    res = kv.retrieve(probe)            # sealed forest answers post-seal
    assert (np.asarray(res.ids) == positions[3]).any()


def test_delete_tombstones_everywhere(rng):
    k_cache = _cache(rng, S=128, hk=2, dh=16)
    kv = KVCacheIndex.prefill(k_cache, jax.random.key(2),
                              KVSpec(delta_capacity=8, m_top=16))
    # sealed position
    assert kv.delete(50) == 1
    assert kv.delete(50) == 0           # idempotent
    res = kv.retrieve(_query_at(k_cache, 50, g=2))
    assert not (np.asarray(res.ids) == 50).any()
    # delta position
    pos = kv.upsert(jnp.asarray(np.asarray(k_cache[:, 50])))
    assert kv.delete(pos) == 1
    res = kv.retrieve(_query_at(k_cache, 50, g=2))
    assert not (np.asarray(res.ids) == pos).any()
    assert kv.n_points == 128 + 1 - 2


def test_upsert_rejects_explicit_gids_and_bad_shapes(rng):
    kv = KVCacheIndex.prefill(_cache(rng, S=64, hk=2, dh=16),
                              jax.random.key(0),
                              KVSpec(delta_capacity=8, m_top=8))
    vec = jnp.zeros((1, 2, 16))
    with pytest.raises(ValueError, match="gids"):
        kv.upsert(vec, gids=np.array([999]))
    with pytest.raises(ValueError, match="expected one key"):
        kv.upsert(jnp.zeros((1, 3, 16)))
    with pytest.raises(ValueError, match="query shape"):
        kv.retrieve(jnp.zeros((2, 1, 4, 16)))


# ----------------------------------------------------------------------
# Protocol surface + decoder loop
# ----------------------------------------------------------------------

def test_kv_index_is_a_mutable_ann_index(rng):
    kv = KVCacheIndex.prefill(_cache(rng, S=128, hk=2, dh=16),
                              jax.random.key(0),
                              KVSpec(delta_capacity=16, m_top=16))
    assert isinstance(kv, MutableAnnIndex)
    assert as_ann_index(kv) is kv
    assert kv.n_points == 128
    assert kv.index_size_bytes() > 0
    assert kv.r_min_for(10) > 0
    with pytest.raises(NotImplementedError, match="prefill"):
        kv.save("/tmp/nope")

    res = kv.search(_query_at(_cache(rng, S=1, hk=2, dh=16), 0, g=2),
                    SearchRequest(k=5))
    assert res.ids.shape == (4, 5) and res.dists.shape == (4, 5)
    assert res.stats.engine == "fused-kv"
    assert res.stats.rounds.shape == (4,)
    assert np.all(np.asarray(res.stats.n_candidates) >= 0)
    # per-lane distances are sorted ascending
    d = np.asarray(res.dists)
    assert np.all(np.diff(d, axis=-1) >= -1e-6)


def test_decode_loop_tracks_exact_attention(rng):
    """Multi-step LSHDecoder loop vs the dense reference on peaky queries:
    one upsert per step, retrieval refreshed every 4, cosine stays high."""
    from repro.models import layers as L
    b, S, hk, g, dh = 1, 384, 2, 2, 32
    prefill = S - 16
    k_cache = _cache(rng, b=b, S=S, hk=hk, dh=dh)
    v_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh))
                          .astype(np.float32))
    kv = KVCacheIndex.prefill(k_cache[:, :prefill], jax.random.key(0),
                              KVSpec(delta_capacity=32, m_top=32,
                                     max_rounds=6))
    dec = LSHDecoder(kv, window=32, sinks=4, refresh_every=4)
    cos = []
    target = 100
    for t in range(16):
        if t % dec.refresh_every == 0:
            target = int(rng.integers(0, prefill))
        length = prefill + t + 1
        q = _query_at(k_cache, target, g, scale=16.0)
        out = dec.step(q, k_cache, v_cache, k_cache[:, length - 1], length)
        ref = L.decode_gqa_attention(q, k_cache, v_cache, length)
        a = np.asarray(out).reshape(-1, dh)
        r = np.asarray(ref).reshape(-1, dh)
        cos.append(np.mean(np.sum(a * r, -1)
                           / (np.linalg.norm(a, axis=-1)
                              * np.linalg.norm(r, axis=-1) + 1e-9)))
    assert dec.n_refreshes == 4
    assert np.mean(cos) > 0.9, cos


def test_sparse_attention_ignores_invalid_positions(rng):
    """-1 (no candidate) must not alias position 0: attention with all
    candidates invalid equals attention over window+sinks alone."""
    b, S, hk, g, dh = 1, 128, 2, 2, 16
    k_cache = _cache(rng, b=b, S=S, hk=hk, dh=dh)
    v_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh))
                          .astype(np.float32))
    q = jnp.asarray(rng.standard_normal((b, 1, hk * g, dh))
                    .astype(np.float32))
    none = jnp.full((b, hk, g, 8), -1, jnp.int32)
    zeros = jnp.zeros((b, hk, g, 8), jnp.int32)
    out_none = sparse_decode_attention(q, k_cache, v_cache, none, S,
                                       window=16, sinks=0)
    out_zero = sparse_decode_attention(q, k_cache, v_cache, zeros, S,
                                       window=16, sinks=0)
    assert not np.allclose(np.asarray(out_none), np.asarray(out_zero))
    out_empty = sparse_decode_attention(
        q, k_cache, v_cache, jnp.full((b, hk, g, 1), -1, jnp.int32), S,
        window=16, sinks=0)
    np.testing.assert_allclose(np.asarray(out_none), np.asarray(out_empty),
                               rtol=1e-5, atol=1e-6)
