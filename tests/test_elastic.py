"""Elastic scaling: checkpoints restore onto a *different* mesh.

A job saved on a (4,)-device data mesh resumes on a (2,2) data×model mesh
(different device count topology) with bit-identical parameters — the
checkpoint stores global arrays and ``restore`` re-shards via the new
mesh's NamedShardings.  This is the restart path for pod loss/gain.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SAVE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={nd}"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, {src!r})
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.train import checkpoint as ckpt

    mesh = make_mesh({shape}, {axes})
    w = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
    sh = NamedSharding(mesh, P({spec}))
    tree = {{"w": jax.device_put(w, sh),
             "b": jnp.arange(8, dtype=jnp.float32)}}
    if {do_save}:
        ckpt.save({d!r}, 3, tree, extra=dict(mesh=str(mesh.shape)))
        print(json.dumps(dict(ok=True)))
    else:
        # elastic path: explicit new-mesh shardings
        out, extra = ckpt.restore({d!r}, 3, tree, shardings={{
            "w": sh, "b": NamedSharding(mesh, P())}})
        ok = bool(jnp.array_equal(out["w"], w) and
                  jnp.array_equal(out["b"], tree["b"]))
        shards = len(out["w"].sharding.device_set)
        print(json.dumps(dict(ok=ok, shards=shards,
                              saved_on=extra.get("mesh"))))
""")


def _run(nd, shape, axes, spec, d, do_save):
    n_axes = axes.count('"') // 2
    script = _SAVE.format(nd=nd, shape=shape, axes=axes, nax=n_axes,
                          spec=spec, d=d, do_save=do_save,
                          src=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_restore_onto_different_mesh(tmp_path):
    d = str(tmp_path / "ck")
    # save on a 4-way pure-data mesh
    r = _run(4, "(4,)", '("data",)', '"data"', d, True)
    assert r["ok"]
    # restore on a 2x2 data-model mesh, sharding w over both axes
    r = _run(4, "(2, 2)", '("data", "model")', '"data", "model"', d, False)
    assert r["ok"], r
    assert r["shards"] == 4
    assert "4" in r["saved_on"]
