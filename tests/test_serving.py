"""Batched LSH serving loop tests."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DETLSH, derive_params
from repro.serving.lsh_service import LSHService
from tests.conftest import brute_force_knn, make_clustered, make_queries_near


def test_service_batches_and_answers(rng):
    data = make_clustered(rng, 4096, 16)
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p,
                       leaf_size=32)
    svc = LSHService(idx, k=5, max_batch=8, pad_to=8)
    svc.warmup(16)

    queries = make_queries_near(data, rng, 20)
    now = time.perf_counter()
    results = svc.serve([(now, q) for q in queries])
    assert len(results) == 20
    assert svc.stats.batches == 3          # 8 + 8 + 4
    assert svc.stats.queries == 20
    s = svc.stats.summary()
    assert s["p99_ms"] >= s["p50_ms"] > 0

    gt_i, _ = brute_force_knn(data, queries, 5)
    recall = np.mean([len(set(np.asarray(results[i][0])) & set(gt_i[i])) / 5
                      for i in range(20)])
    assert recall >= 0.6, recall
