"""Batched LSH serving loop tests."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SearchRequest
from repro.core import DETLSH, derive_params
from repro.serving.lsh_service import LSHService
from tests.conftest import brute_force_knn, make_clustered, make_queries_near


def test_service_batches_and_answers(rng):
    data = make_clustered(rng, 4096, 16)
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p,
                       leaf_size=32)
    svc = LSHService(idx, k=5, max_batch=8, pad_to=8)
    svc.warmup(16)

    queries = make_queries_near(data, rng, 20)
    now = time.perf_counter()
    results = svc.serve([(now, q) for q in queries])
    assert len(results) == 20
    assert svc.stats.batches == 3          # 8 + 8 + 4
    assert svc.stats.queries == 20
    s = svc.stats.summary()
    assert s["p99_ms"] >= s["p50_ms"] > 0

    gt_i, _ = brute_force_knn(data, queries, 5)
    recall = np.mean([len(set(np.asarray(results[i][0])) & set(gt_i[i])) / 5
                      for i in range(20)])
    assert recall >= 0.6, recall


def test_pad_lanes_done_from_round_zero(rng):
    """Pad lanes of a partial batch carry r_eff = -1 from round 0: they run
    zero radius rounds and admit zero candidates, for both engines."""
    data = make_clustered(rng, 2048, 16)
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p, leaf_size=32)
    queries = make_queries_near(data, rng, 3)
    padded = np.concatenate([queries, np.zeros((13, 16), np.float32)])
    for engine in ("fused", "vmap"):
        res = idx.search(jnp.asarray(padded),
                         SearchRequest(k=5, engine=engine, n_active=3))
        rounds = np.asarray(res.stats.rounds)
        assert np.all(rounds[3:] == 0), (engine, rounds)
        assert np.all(rounds[:3] >= 1), (engine, rounds)
        assert np.all(np.asarray(res.stats.n_candidates)[3:] == 0), engine
        # real lanes are unaffected by the padding
        ref = idx.search(jnp.asarray(padded), SearchRequest(k=5, engine=engine))
        np.testing.assert_array_equal(np.asarray(res.ids)[:3],
                                      np.asarray(ref.ids)[:3])


def test_stats_do_not_count_pad_lanes(rng):
    """The regression gate for the serving satellite: a 20-request stream
    over max_batch=8 issues one 4-real/4-pad batch; pad lanes appear in
    stats.pad_queries only — never in queries or the latency samples."""
    data = make_clustered(rng, 2048, 16)
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p, leaf_size=32)
    svc = LSHService(idx, k=5, max_batch=8, pad_to=8)
    queries = make_queries_near(data, rng, 20)
    svc.serve([(time.perf_counter(), q) for q in queries])
    assert svc.stats.queries == 20
    assert svc.stats.batches == 3
    assert svc.stats.pad_queries == 4
    assert len(svc.stats.latencies_ms) == 20
    assert svc.stats.summary()["pad_queries"] == 4


def test_service_upsert_delete_with_compaction(rng):
    """The mutable service loop: upsert/delete hit the streaming index and
    the compaction trigger fires once the segment fan-out grows."""
    from repro.streaming import StreamingDETLSH

    data = make_clustered(rng, 1024, 16)
    p = derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    idx = StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0), p,
                                Nr=32, leaf_size=16, delta_capacity=32,
                                max_segments=2)
    svc = LSHService(idx, k=5, max_batch=8, pad_to=8)

    probe = (data[0] + 40.0).astype(np.float32)
    [gid] = svc.upsert(probe)
    res = svc.serve([(time.perf_counter(), probe)])
    assert int(res[0][0][0]) == int(gid)          # fresh insert served

    svc.delete([gid])
    res = svc.serve([(time.perf_counter(), probe)])
    assert int(res[0][0][0]) != int(gid)          # tombstone honored

    svc.upsert(make_clustered(rng, 128, 16))      # 4 seals -> compaction
    assert svc.stats.compactions >= 1
    assert len(idx.manifest.segments) <= 2
    assert svc.stats.upserts == 129 and svc.stats.deletes == 1


def test_service_works_without_n_active_support(rng):
    """Indexes whose query() lacks the n_active kwarg (PDET shard_map,
    baselines) must still serve — pad-lane masking is an optimization."""
    class LegacyIndex:
        def __init__(self, idx):
            self._idx = idx

        def query(self, queries, k=10):
            # A pre-protocol surface; implemented on the typed search so
            # the suite stays clean under -W error::DeprecationWarning.
            return self._idx.search(queries, SearchRequest(k=k)).raw

    data = make_clustered(rng, 512, 8)
    p = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p, leaf_size=16)
    svc = LSHService(LegacyIndex(idx), k=3, max_batch=4, pad_to=4)
    assert not svc._supports_n_active
    results = svc.serve([(time.perf_counter(), q)
                         for q in make_queries_near(data, rng, 6)])
    assert len(results) == 6
    assert svc.stats.queries == 6 and svc.stats.pad_queries == 2


def test_static_index_rejects_mutation(rng):
    data = make_clustered(rng, 256, 8)
    p = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p, leaf_size=16)
    svc = LSHService(idx, k=3)
    import pytest
    with pytest.raises(TypeError):
        svc.upsert(np.zeros((1, 8), np.float32))
