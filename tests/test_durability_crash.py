"""Crash-point injection matrix (docs/DESIGN.md §13).

Hypothesis-generated interleavings of {upsert, delete, seal, compact,
checkpoint} are killed mid-flight at each durability boundary
(WAL_APPEND / WAL_FSYNC / SNAPSHOT_WRITE / CHECKPOINT_INSTALL, with a
skip offset choosing *which* crossing dies), and recovery must be
bit-identical to the pre-crash index over the acked ops:

  * the recovered index's saturating answers equal brute force over the
    expected survivor set, on BOTH engines;
  * a from-scratch static rebuild (and, in a fixed case, a
    PDET-resharded rebuild) over the same survivors answers identically;
  * no crash point leaves the root without a loadable checkpoint.

The expected survivor set is deterministic per crash site: a WAL_APPEND
crash fires before any byte is logged (the in-flight op never happened);
a WAL_FSYNC crash fires after the record is written + flushed (an
in-process kill keeps it, so replay applies it); snapshot/checkpoint
crossings never touch the answer set.  Run with ``pytest -m crash``.
"""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.api
from repro.api import IndexSpec, PlacementSpec, SearchRequest, persist
from repro.core import DETLSH, derive_params
from repro.durability import DurableIndex, FSYNC_ALWAYS, recover
from repro.serving import (CHECKPOINT_INSTALL, FaultPlan, InjectedFault,
                           SNAPSHOT_WRITE, WAL_APPEND, WAL_FSYNC)
from repro.streaming import StreamingDETLSH

pytestmark = pytest.mark.crash

D = 8
K_NN = 4
SAT = dict(r_min=1e6, M=10**6)
PARAMS = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
KW = dict(Nr=8, leaf_size=8, delta_capacity=16, max_segments=2)
CRASH_SITES = (WAL_APPEND, WAL_FSYNC, SNAPSHOT_WRITE, CHECKPOINT_INSTALL)


def _expected_answers(expected, queries, k):
    """Brute-force exact top-k over the expected survivor map."""
    gids = np.array(sorted(expected), dtype=np.int64)
    vecs = np.stack([expected[g] for g in gids])
    d2 = ((queries[:, None, :] - vecs[None, :, :]) ** 2).sum(-1)
    sel = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return gids[sel], np.sqrt(np.take_along_axis(d2, sel, axis=1))


def _check_answers(index, expected, queries, tag):
    gt_gids, gt_d = _expected_answers(expected, queries, K_NN)
    for engine in ("fused", "vmap"):
        res = index.search(jnp.asarray(queries),
                           SearchRequest(k=K_NN, engine=engine, **SAT))
        ids = np.asarray(res.ids)[:, :K_NN]
        np.testing.assert_allclose(np.asarray(res.dists)[:, :K_NN], gt_d,
                                   rtol=1e-4, atol=1e-4,
                                   err_msg=f"{tag}:{engine}")
        for b in range(len(queries)):      # same ids up to distance ties
            assert set(ids[b].tolist()) == set(gt_gids[b].tolist()), \
                (tag, engine, b)


def _drive(root, rng, ops, site, skip):
    """Run ``ops`` against a DurableIndex with ``site`` armed (after the
    first ``skip`` crossings), killing the process model at the injected
    fault.  Returns the expected survivor map and whether a fault fired."""
    data = rng.standard_normal((32, D)).astype(np.float32)
    idx = StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0),
                                PARAMS, **KW)
    plan = FaultPlan()
    dix = DurableIndex.create(idx, root, fsync=FSYNC_ALWAYS,
                              keep_checkpoints=2, fault_plan=plan)
    expected = {g: data[g] for g in range(len(data))}
    plan.arm(site, times=1, skip=skip)     # armed only after create()

    crashed = None
    for kind, arg in ops:
        try:
            if kind == "upsert":
                vecs = rng.standard_normal((arg, D)).astype(np.float32)
                gids = np.arange(dix.next_gid, dix.next_gid + arg,
                                 dtype=np.int64)
                pending = ("upsert", dict(zip(gids.tolist(), vecs)))
                dix.upsert(vecs, gids)
                expected.update(pending[1])
            elif kind == "delete":
                live = sorted(expected)
                gids = np.array(live[:: max(1, len(live) // arg)][:arg],
                                dtype=np.int64)
                pending = ("delete", gids.tolist())
                dix.delete(gids)
                for g in pending[1]:
                    expected.pop(g, None)
            elif kind == "seal":
                pending = ("seal", None)
                dix.seal()
            elif kind == "compact":
                pending = ("compact", None)
                dix.compact()
            else:
                pending = ("checkpoint", None)
                dix.checkpoint()
        except InjectedFault:
            crashed = pending
            break

    # A WAL_FSYNC crash fires AFTER the record hit the (flushed) log, so
    # replay applies the in-flight data op; every other site's crash
    # happens before the op is logged, or in an answer-preserving one.
    if crashed is not None and site == WAL_FSYNC:
        op, detail = crashed
        if op == "upsert":
            expected.update(detail)
        elif op == "delete":
            for g in detail:
                expected.pop(g, None)
    dix.wal._f.close()                     # the kill: no flush, no fsync
    return expected, crashed is not None


def _static_rebuild_answers(expected, queries):
    """Exact answers from a from-scratch static build over the survivors
    (gids remapped: a static build numbers rows 0..n-1)."""
    gids = np.array(sorted(expected), dtype=np.int64)
    vecs = np.stack([expected[g] for g in gids]).astype(np.float32)
    st_idx = DETLSH.build(jnp.asarray(vecs), jax.random.key(7), PARAMS,
                          Nr=8, leaf_size=8)
    res = st_idx.search(jnp.asarray(queries),
                        SearchRequest(k=K_NN, **SAT))
    return gids[np.asarray(res.ids)[:, :K_NN]], \
        np.asarray(res.dists)[:, :K_NN]


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.lists(st.tuples(st.sampled_from(["upsert", "delete", "seal",
                                           "compact", "checkpoint"]),
                          st.integers(min_value=1, max_value=8)),
                min_size=3, max_size=7),
       st.sampled_from(CRASH_SITES),
       st.integers(min_value=0, max_value=2))
@pytest.mark.timeout(600)
def test_crash_matrix_recovery_is_bit_identical(seed, ops, site, skip):
    rng = np.random.default_rng(seed)
    tmp = tempfile.mkdtemp(prefix="crash-matrix-")
    try:
        root = os.path.join(tmp, "root")
        expected, fired = _drive(root, rng, ops, site, skip)
        queries = rng.standard_normal((3, D)).astype(np.float32)

        rec = recover(root)
        try:
            assert rec.n_points == len(expected), (site, skip, fired)
            _check_answers(rec, expected, queries, (site, skip, fired))
            # and a from-scratch static rebuild over the survivors agrees
            st_gids, st_d = _static_rebuild_answers(expected, queries)
            gt_gids, gt_d = _expected_answers(expected, queries, K_NN)
            np.testing.assert_allclose(st_d, gt_d, rtol=1e-4, atol=1e-4)
            for b in range(len(queries)):
                assert set(st_gids[b].tolist()) == set(gt_gids[b].tolist())
        finally:
            rec.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


@pytest.mark.parametrize("site", CRASH_SITES)
@pytest.mark.parametrize("skip", [0, 1])
@pytest.mark.timeout(600)
def test_crash_each_site_deterministic(tmp_path, site, skip):
    """Every site × {first, second} crossing on one fixed interleaving —
    guarantees full matrix coverage independent of hypothesis choices."""
    rng = np.random.default_rng(0xC0FFEE)
    ops = [("upsert", 6), ("seal", 1), ("checkpoint", 1), ("delete", 3),
           ("upsert", 4), ("compact", 1), ("checkpoint", 1)]
    root = str(tmp_path / "root")
    expected, fired = _drive(root, rng, ops, site, skip)
    assert fired                           # this interleaving crosses all
    queries = rng.standard_normal((3, D)).astype(np.float32)
    rec = recover(root)
    try:
        assert rec.n_points == len(expected)
        _check_answers(rec, expected, queries, (site, skip))
    finally:
        rec.close()


@pytest.mark.timeout(600)
def test_no_crash_leaves_valid_checkpoint_unloadable(tmp_path):
    """After a kill at EVERY boundary of a checkpoint-heavy interleaving,
    at least one checkpoint under the root must still pass digest
    verification and load — the acceptance bar of §13."""
    for i, site in enumerate(CRASH_SITES):
        for skip in (0, 1, 2):
            rng = np.random.default_rng(i * 31 + skip)
            root = str(tmp_path / f"root_{site}_{skip}")
            ops = [("upsert", 4), ("checkpoint", 1), ("delete", 2),
                   ("checkpoint", 1), ("upsert", 3), ("checkpoint", 1)]
            _drive(root, rng, ops, site, skip)
            ckpt_dir = os.path.join(root, "checkpoints")
            names = sorted(n for n in os.listdir(ckpt_dir)
                           if n.startswith("ckpt_"))
            loaded = 0
            for name in names:
                try:
                    persist.load(os.path.join(ckpt_dir, name))
                    loaded += 1
                except persist.SnapshotFormatError:
                    pass                   # partial publish: skippable
            assert loaded >= 1, (site, skip, names)


@pytest.mark.timeout(600)
def test_pdet_resharded_rebuild_matches_recovery(tmp_path):
    """A PDET-sharded from-scratch build over the recovered survivors
    answers identically to the recovered streaming index (the §13
    resharding acceptance case; 1-device mesh in tier-1, 4 in the
    multidevice CI job)."""
    rng = np.random.default_rng(11)
    ops = [("upsert", 8), ("seal", 1), ("delete", 3), ("checkpoint", 1),
           ("upsert", 5)]
    root = str(tmp_path / "root")
    expected, _ = _drive(root, rng, ops, WAL_APPEND, 2)
    queries = rng.standard_normal((3, D)).astype(np.float32)

    rec = recover(root)
    try:
        _check_answers(rec, expected, queries, "pdet-pre")
        gids = np.array(sorted(expected), dtype=np.int64)
        vecs = np.stack([expected[g] for g in gids]).astype(np.float32)
        spec = IndexSpec(kind="static", K=2, L=2, c=1.5, beta_override=0.1,
                         Nr=8, leaf_size=8,
                         placement=PlacementSpec(
                             mesh_shape=(len(jax.devices()),),
                             mesh_axes=("data",)))
        pdet = repro.api.build(jnp.asarray(vecs), jax.random.key(3), spec)
        res = pdet.search(jnp.asarray(queries),
                          SearchRequest(k=K_NN, **SAT))
        gt_gids, gt_d = _expected_answers(expected, queries, K_NN)
        np.testing.assert_allclose(np.asarray(res.dists)[:, :K_NN], gt_d,
                                   rtol=1e-4, atol=1e-4)
        ids = gids[np.asarray(res.ids)[:, :K_NN]]
        for b in range(len(queries)):
            assert set(ids[b].tolist()) == set(gt_gids[b].tolist())
    finally:
        rec.close()
