"""Baseline sanity: each method beats random and approaches the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import HNSW, IVFPQ, BruteForce, C2LSH, E2LSH, PMLSH
from tests.conftest import (brute_force_knn, make_clustered,
                            make_queries_near)


@pytest.fixture(scope="module")
def ds(rng):
    data = make_clustered(rng, 4096, 24)
    queries = make_queries_near(data, rng, 8)
    gt_i, gt_d = brute_force_knn(data, queries, 10)
    return jnp.asarray(data), jnp.asarray(queries), gt_i, gt_d


def _recall(ids, gt_i):
    ids = np.asarray(ids)
    return np.mean([len(set(ids[i]) & set(gt_i[i])) / gt_i.shape[1]
                    for i in range(len(gt_i))])


def test_brute_force_exact(ds):
    data, queries, gt_i, gt_d = ds
    idx = BruteForce.build(data)
    ids, d = idx.query(queries, 10)
    np.testing.assert_allclose(np.asarray(d), gt_d, rtol=1e-4, atol=1e-4)
    assert _recall(ids, gt_i) == 1.0


def test_e2lsh_recall(ds):
    data, queries, gt_i, _ = ds
    idx = E2LSH.build(data, jax.random.key(0), K=6, L=8, w=6.0)
    ids, d = idx.query(queries, 10)
    assert _recall(ids, gt_i) >= 0.4
    assert idx.size_bytes() > 0


def test_c2lsh_recall(ds):
    data, queries, gt_i, _ = ds
    idx = C2LSH.build(data, jax.random.key(1), m=24, w=2.0,
                      threshold_frac=0.4)
    ids, d = idx.query(queries, 10, r=1.0)
    assert _recall(ids, gt_i) >= 0.5


def test_pmlsh_recall(ds):
    data, queries, gt_i, _ = ds
    idx = PMLSH.build(data, jax.random.key(2), K=15, beta=0.1)
    ids, d = idx.query(queries, 10)
    assert _recall(ids, gt_i) >= 0.7


def test_hnsw_recall(ds):
    data, queries, gt_i, _ = ds
    idx = HNSW.build(np.asarray(data), M=12, ef_construction=48)
    ids, d = idx.query(np.asarray(queries), 10, ef_search=128)
    assert _recall(ids, gt_i) >= 0.8


def test_ivfpq_recall(ds):
    data, queries, gt_i, _ = ds
    idx = IVFPQ.build(data, jax.random.key(3), nlist=32, M=4, nprobe=8,
                      rerank=256)
    ids, d = idx.query(queries, 10)
    assert _recall(ids, gt_i) >= 0.6


def test_reported_distances_are_true_distances(ds):
    data, queries, gt_i, _ = ds
    for idx in (PMLSH.build(data, jax.random.key(2)),
                IVFPQ.build(data, jax.random.key(3), nlist=16, M=4)):
        ids, d = idx.query(queries, 5)
        ids, d = np.asarray(ids), np.asarray(d)
        ok = ids < data.shape[0]
        true = np.sqrt((((np.asarray(data)[np.clip(ids, 0, None)]
                          - np.asarray(queries)[:, None]) ** 2).sum(-1)))
        np.testing.assert_allclose(d[ok], true[ok], rtol=1e-4, atol=1e-4)
