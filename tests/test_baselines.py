"""Baseline sanity: each method beats random and approaches the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import HNSW, IVFPQ, BruteForce, C2LSH, E2LSH, PMLSH
from tests.conftest import (brute_force_knn, make_clustered,
                            make_queries_near)


@pytest.fixture(scope="module")
def ds(rng):
    data = make_clustered(rng, 4096, 24)
    queries = make_queries_near(data, rng, 8)
    gt_i, gt_d = brute_force_knn(data, queries, 10)
    return jnp.asarray(data), jnp.asarray(queries), gt_i, gt_d


def _recall(ids, gt_i):
    ids = np.asarray(ids)
    return np.mean([len(set(ids[i]) & set(gt_i[i])) / gt_i.shape[1]
                    for i in range(len(gt_i))])


def test_brute_force_exact(ds):
    data, queries, gt_i, gt_d = ds
    idx = BruteForce.build(data)
    ids, d = idx.query(queries, 10)
    np.testing.assert_allclose(np.asarray(d), gt_d, rtol=1e-4, atol=1e-4)
    assert _recall(ids, gt_i) == 1.0


def test_e2lsh_recall(ds):
    data, queries, gt_i, _ = ds
    idx = E2LSH.build(data, jax.random.key(0), K=6, L=8, w=6.0)
    ids, d = idx.query(queries, 10)
    assert _recall(ids, gt_i) >= 0.4
    assert idx.size_bytes() > 0


def test_c2lsh_recall(ds):
    data, queries, gt_i, _ = ds
    idx = C2LSH.build(data, jax.random.key(1), m=24, w=2.0,
                      threshold_frac=0.4)
    ids, d = idx.query(queries, 10, r=1.0)
    assert _recall(ids, gt_i) >= 0.5


def test_pmlsh_recall(ds):
    data, queries, gt_i, _ = ds
    idx = PMLSH.build(data, jax.random.key(2), K=15, beta=0.1)
    ids, d = idx.query(queries, 10)
    assert _recall(ids, gt_i) >= 0.7


def test_hnsw_recall(ds):
    data, queries, gt_i, _ = ds
    idx = HNSW.build(np.asarray(data), M=12, ef_construction=48)
    ids, d = idx.query(np.asarray(queries), 10, ef_search=128)
    assert _recall(ids, gt_i) >= 0.8


def test_ivfpq_recall(ds):
    data, queries, gt_i, _ = ds
    idx = IVFPQ.build(data, jax.random.key(3), nlist=32, M=4, nprobe=8,
                      rerank=256)
    ids, d = idx.query(queries, 10)
    assert _recall(ids, gt_i) >= 0.6


def _protocol_builders():
    return [
        ("brute-force", lambda d, k: BruteForce.build(d)),
        ("pm-lsh", lambda d, k: PMLSH.build(d, k, beta=0.1)),
        ("ivf-pq", lambda d, k: IVFPQ.build(d, k, nlist=32, M=4, nprobe=8,
                                            rerank=256)),
        ("hnsw", lambda d, k: HNSW.build(np.asarray(d), None, M=8,
                                         ef_construction=32)),
    ]


@pytest.mark.parametrize("name,build",
                         _protocol_builders(),
                         ids=[n for n, _ in _protocol_builders()])
def test_baseline_conforms_to_ann_index_protocol(ds, name, build):
    """Every baseline answers the same ``AnnIndex`` surface the Pareto
    harness drives (docs/DESIGN.md §10): native protocol, no adapter."""
    from repro.api import AnnIndex, SearchRequest, as_ann_index
    data, queries, gt_i, _ = ds
    idx = build(data, jax.random.key(9))
    assert isinstance(idx, AnnIndex)
    assert as_ann_index(idx) is idx           # no LegacyIndexAdapter wrap
    assert idx.n_points == data.shape[0]
    assert idx.index_size_bytes() >= 0      # brute-force owns no structure
    assert idx.r_min_for(10) > 0
    with pytest.raises(NotImplementedError):
        idx.save("/tmp/nope")

    res = idx.search(queries, SearchRequest(k=5))
    assert res.ids.shape == (queries.shape[0], 5)
    assert res.dists.shape == (queries.shape[0], 5)
    assert res.stats.engine == name
    work = np.asarray(res.stats.n_candidates)
    assert work.shape == (queries.shape[0],)
    # cost model: positive, and never claims more than a full scan
    # (hnsw counts real distance evaluations; the others count their
    # candidate budget)
    assert np.all(work > 0)
    if name != "hnsw":
        assert np.all(work <= data.shape[0])
    assert _recall(res.ids, gt_i[:, :5]) >= 0.5


def test_reported_distances_are_true_distances(ds):
    data, queries, gt_i, _ = ds
    for idx in (PMLSH.build(data, jax.random.key(2)),
                IVFPQ.build(data, jax.random.key(3), nlist=16, M=4)):
        ids, d = idx.query(queries, 5)
        ids, d = np.asarray(ids), np.asarray(d)
        ok = ids < data.shape[0]
        true = np.sqrt((((np.asarray(data)[np.clip(ids, 0, None)]
                          - np.asarray(queries)[:, None]) ** 2).sum(-1)))
        np.testing.assert_allclose(d[ok], true[ok], rtol=1e-4, atol=1e-4)
