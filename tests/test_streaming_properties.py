"""Property test: streaming mutations never change what a query can see.

For a generated sequence of {insert-batch, delete, flush, compact} ops, a
saturating query (every leaf admitted, exact rerank) over the segmented
index must return exactly the brute-force top-k of the surviving union —
which is precisely what a from-scratch ``build_forest`` on the survivors
returns at the same configuration (every point reranked exactly), so this
is the "identical to a fresh static build" equivalence, made deterministic.
Checked for both engines; deleted ids must never surface, including before
any compaction runs.

Uses hypothesis when installed; otherwise the deterministic shim in
tests/_shims supplies the same API.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import SearchRequest
from repro.core import derive_params
from repro.streaming import StreamingDETLSH

D = 8
SAT = dict(r_min=1e6, M=10**6)
PARAMS = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
# One fixed geometry => one compile per (engine, shape) across all examples.
KW = dict(Nr=8, leaf_size=8, delta_capacity=16, max_segments=2)


def _apply_ops(idx, rng, ops):
    deleted = set()
    for kind, arg in ops:
        if kind == "insert":
            vecs = rng.standard_normal((arg, D)).astype(np.float32)
            idx.upsert(vecs)
        elif kind == "delete":
            alive = sorted(idx.locator.keys())
            if alive:
                kill = rng.choice(alive, size=min(arg, len(alive)),
                                  replace=False)
                idx.delete(kill)
                deleted.update(int(g) for g in kill)
        elif kind == "flush":
            idx.flush()
        elif kind == "compact":
            idx.compact()
        idx.maybe_compact()
    return deleted


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.lists(st.tuples(st.sampled_from(["insert", "delete", "flush",
                                           "compact"]),
                          st.integers(min_value=1, max_value=24)),
                min_size=2, max_size=6))
def test_mutation_sequence_equals_fresh_build(seed, ops):
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((48, D)).astype(np.float32)
    idx = StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0),
                                PARAMS, **KW)
    deleted = _apply_ops(idx, rng, ops)

    queries = rng.standard_normal((4, D)).astype(np.float32)
    vecs, gids = idx._survivors()
    assert len(gids) == idx.n_live == 48 + sum(
        a for k, a in ops if k == "insert") - len(deleted)
    if len(gids) == 0:
        return
    k = min(5, len(gids))
    d2 = ((queries[:, None, :] - vecs[None, :, :]) ** 2).sum(-1)
    sel = np.argsort(d2, axis=1, kind="stable")[:, :k]
    gt_gids = gids[sel]
    gt_d = np.sqrt(np.take_along_axis(d2, sel, axis=1))

    for engine in ("fused", "vmap"):
        res = idx.search(jnp.asarray(queries),
                         SearchRequest(k=k, engine=engine, **SAT))
        ids = np.asarray(res.ids)[:, :k]
        np.testing.assert_allclose(np.asarray(res.dists)[:, :k], gt_d,
                                   rtol=1e-4, atol=1e-4, err_msg=engine)
        for b in range(len(queries)):      # same ids up to distance ties
            assert set(ids[b]) == set(gt_gids[b]), (engine, b)
        assert not (set(ids.ravel()) & deleted), engine
