"""PDET-LSH distributed runtime tests.

The key claim (paper Theorem 3 + §IV-C): the parallel execution returns
*identical* results to the sequential execution of the same algorithm.  We
verify (a) the serial sharded reference against the plain single-shard
DET-LSH quality contract, and (b) the real shard_map execution on 8
placeholder devices against the serial reference — exact id/distance match.

Multi-device tests run in a subprocess because XLA device count is fixed at
first jax initialization.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import derive_params
from repro.core.distributed import (serial_reference_build,
                                    serial_reference_query)
from repro.core.query import QueryConfig
from tests.conftest import brute_force_knn, make_clustered

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _serial(n_shards, n=4096, d=16, k=5, nq=4, seed=0):
    rng = np.random.default_rng(seed)
    data = make_clustered(rng, n, d)
    queries = make_clustered(rng, nq, d)
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.1)
    A, parts, edges = serial_reference_build(
        jnp.asarray(data), jax.random.key(0), p, n_shards, leaf_size=32)
    cfg = QueryConfig(k=k, M=8, r_min=0.5)
    ids, dists = serial_reference_query(jnp.asarray(data), A, parts, p,
                                        jnp.asarray(queries), cfg, n_shards,
                                        32)
    return data, queries, np.asarray(ids), np.asarray(dists), p


def test_serial_reference_quality():
    data, queries, ids, dists, p = _serial(n_shards=4)
    gt_i, gt_d = brute_force_knn(data, queries, 5)
    assert np.all(dists <= p.c ** 2 * gt_d + 1e-4)
    n = data.shape[0]
    assert np.all((ids >= 0) & (ids < n))
    # distances are true distances of the returned global ids
    true = np.sqrt(((data[ids] - queries[:, None, :]) ** 2).sum(-1))
    np.testing.assert_allclose(dists, true, rtol=1e-4, atol=1e-4)


def test_shard_count_invariance_of_breakpoints():
    """Global psum'd histogram breakpoints are shard-count independent."""
    rng = np.random.default_rng(3)
    data = make_clustered(rng, 2048, 8)
    p = derive_params(K=4, c=1.5, L=2)
    _, _, e1 = serial_reference_build(jnp.asarray(data), jax.random.key(0),
                                      p, 1, leaf_size=32)
    _, _, e8 = serial_reference_build(jnp.asarray(data), jax.random.key(0),
                                      p, 8, leaf_size=32)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e8), rtol=1e-5,
                               atol=1e-5)


_SUBPROCESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, {repo_src!r}); sys.path.insert(0, {repo!r})
    from repro.launch.mesh import make_mesh
    from repro.core import derive_params
    from repro.core.distributed import build_pdet
    from repro.core.query import QueryConfig
    from tests.conftest import make_clustered

    rng = np.random.default_rng({seed})
    data = make_clustered(rng, {n}, {d})
    queries = make_clustered(rng, {nq}, {d})
    p = derive_params(K=4, c=1.5, L=8, beta_override=0.1)
    mesh = make_mesh({mesh_shape}, {mesh_axes})
    idx = build_pdet(jnp.asarray(data), jax.random.key(0), p, mesh,
                     axes={data_axes}, leaf_size=32)
    res = idx.query(jnp.asarray(queries), k={k}, M=8, r_min=0.5)
    ids, dists, rounds = (np.asarray(r) for r in res)
    print(json.dumps(dict(ids=ids.tolist(), dists=dists.tolist())))
""")


def _run_multi_device(mesh_shape, mesh_axes, data_axes, n=4096, d=16, k=5,
                      nq=4, seed=0):
    script = _SUBPROCESS.format(repo=REPO, repo_src=os.path.join(REPO, "src"),
                                n=n, d=d, k=k, nq=nq, seed=seed,
                                mesh_shape=mesh_shape, mesh_axes=mesh_axes,
                                data_axes=data_axes)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    return np.asarray(payload["ids"]), np.asarray(payload["dists"])


@pytest.mark.slow
def test_multidevice_matches_serial_reference():
    """8 real (placeholder) devices == serial sharded reference, exactly."""
    ids_m, dists_m = _run_multi_device((8,), ("data",), ("data",))
    _, _, ids_s, dists_s, _ = _serial(n_shards=8)
    np.testing.assert_allclose(dists_m, dists_s, rtol=1e-5, atol=1e-5)
    assert (ids_m == ids_s).mean() > 0.95  # ties may reorder equidistant ids


@pytest.mark.slow
def test_multipod_mesh_axes():
    """Sharding over ('pod','data') jointly — the multi-pod configuration."""
    ids_m, dists_m = _run_multi_device((2, 4), ("pod", "data"),
                                       ("pod", "data"))
    _, _, ids_s, dists_s, _ = _serial(n_shards=8)
    np.testing.assert_allclose(dists_m, dists_s, rtol=1e-5, atol=1e-5)


_CP_DECODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, {repo_src!r}); sys.path.insert(0, {repo!r})
    from repro.launch.mesh import make_mesh
    from repro.models import layers as L
    from repro.sharding.rules import ShardingRules, use_rules

    rng = np.random.default_rng(0)
    b, S, hk, g, dh = 2, 64, 2, 2, 16
    h = hk * g
    q = jnp.asarray(rng.standard_normal((b, 1, h, dh)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(np.float32))
    ref = np.asarray(L.decode_gqa_attention(q, k, v, 50))

    mesh = make_mesh((2, 4), ("data", "model"))
    rules = ShardingRules(mesh)
    with use_rules(rules), mesh:
        got = np.asarray(jax.jit(
            lambda q, k, v: L.decode_gqa_attention(q, k, v, 50))(q, k, v))
    err = float(np.abs(got - ref).max())
    print(json.dumps(dict(err=err)))
""")


@pytest.mark.slow
def test_cp_flash_decode_matches_reference():
    """shard_map context-parallel decode == single-device decode."""
    script = _CP_DECODE.format(repo=REPO,
                               repo_src=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["err"] < 1e-4, payload
