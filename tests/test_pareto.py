"""The Pareto harness (repro.eval.pareto): front extraction, the
dominance gate, and a tiny end-to-end run through the protocol."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.eval import (CurvePoint, dominates_at_recall, pareto_front,
                        run_pareto)
from tests.conftest import make_clustered, make_queries_near


def _pt(method, label, recall, qps, work):
    return CurvePoint(method=method, label=label, recall=recall, qps=qps,
                      work_per_query=work, build_seconds=0.0,
                      index_bytes=0, params={})


def test_pareto_front_qps_and_work_axes():
    pts = [_pt("a", "p0", 0.9, 100.0, 500),    # qps-front; p1 beats on work
           _pt("a", "p1", 0.9, 50.0, 400),     # work-front; p0 beats on qps
           _pt("a", "p2", 0.8, 80.0, 600),     # dominated on both axes
           _pt("a", "p3", 1.0, 10.0, 8192)]    # best recall: front on both
    assert pareto_front(pts, y="qps") == [0, 3]
    assert pareto_front(pts, y="work_per_query") == [1, 3]


def test_dominates_at_recall_gate():
    pts = [_pt("brute-force", "scan", 1.0, 10.0, 8192),
           _pt("det-lsh", "lo", 0.5, 90.0, 100),
           _pt("det-lsh", "hi", 0.95, 40.0, 3000)]
    gate = dominates_at_recall(pts, min_recall=0.9)
    assert gate["ok"] and gate["best_label"] == "hi"
    assert gate["best_work"] == 3000 and gate["reference_work"] == 8192
    # no qualifying det-lsh point -> explicit, reasoned failure
    gate = dominates_at_recall(pts[:2], min_recall=0.9)
    assert not gate["ok"] and "recall" in gate["reason"]
    gate = dominates_at_recall(pts[1:], min_recall=0.9)
    assert not gate["ok"] and "brute-force" in gate["reason"]


def test_run_pareto_end_to_end_tiny(rng):
    """A tiny full sweep: det-lsh + brute-force + one baseline through the
    same protocol, JSON-shaped output, gate evidence present."""
    from repro.api import IndexSpec
    from repro.baselines import PMLSH

    data = jnp.asarray(make_clustered(rng, 2048, 16))
    queries = jnp.asarray(make_queries_near(np.asarray(data), rng, 4))
    key = jax.random.PRNGKey(0)
    pm = PMLSH.build(data, key, beta=0.1)
    out = run_pareto(
        data, queries, key, k=5,
        specs=[IndexSpec(K=4, L=4, beta_override=0.1, Nr=64, leaf_size=32)],
        Ms=(8,), max_rounds=(16,), engines=("fused",),
        baselines={"pm-lsh": [("b0.1", pm, 0.1, dict(beta=0.1))]},
        repeat=1, min_recall=0.5)

    assert out["methods"] == ["brute-force", "det-lsh", "pm-lsh"]
    assert len(out["points"]) == 3
    import json
    json.dumps(out)                       # BENCH_pareto.json-ready
    by = {p["method"]: p for p in out["points"]}
    assert by["brute-force"]["recall"] == 1.0
    assert by["brute-force"]["work_per_query"] == 2048
    assert by["det-lsh"]["work_per_query"] < 2048
    assert out["front_qps"] and out["front_work"]
    gate = out["det_dominates_brute"]
    assert set(gate) >= {"ok", "min_recall"}
