"""Streaming segmented index: inserts, tombstone deletes, compaction.

The load-bearing contract (ISSUE acceptance): after any sequence of
inserts/deletes/seals/compactions, querying at a *saturating* configuration
(radius large enough to admit every leaf, M >= n_leaves) returns the exact
top-k of the surviving point set — identical to a from-scratch static build
on the surviving union — for both engines, and deleted ids are never
returned even before compaction runs.  At saturation both indexes rerank
every live point exactly, so equality is deterministic, not statistical;
the randomized version lives in tests/test_streaming_properties.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SearchRequest
from repro.core import DETLSH, derive_params
from repro.streaming import StreamingDETLSH, merge_segments
from repro.streaming.compactor import interleave_keys64, \
    stable_merge_positions
from tests.conftest import make_clustered

D = 16
SAT = dict(r_min=1e6, M=10**6)         # saturating query: admit everything


def make_index(rng, n=600, **kw):
    data = make_clustered(rng, n, D)
    p = derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    kw.setdefault("Nr", 32)
    kw.setdefault("leaf_size", 16)
    kw.setdefault("delta_capacity", 64)
    kw.setdefault("max_segments", 3)
    idx = StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0), p, **kw)
    return idx, data


def survivors_bf(idx, queries, k):
    """Brute-force exact top-k (gids, dists) over the surviving union."""
    vecs, gids = idx._survivors()
    d2 = ((queries[:, None, :] - vecs[None, :, :]) ** 2).sum(-1)
    sel = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return gids[sel], np.sqrt(np.take_along_axis(d2, sel, axis=1))


@pytest.fixture(scope="module")
def idx_and_data():
    rng = np.random.default_rng(11)
    idx, data = make_index(rng)
    new = make_clustered(rng, 150, D)
    gids_new = idx.upsert(new)
    idx.delete(np.arange(0, 40))           # base deletes (sealed segment)
    idx.delete(gids_new[:10])              # delta + sealed-delta deletes
    queries = make_clustered(rng, 8, D)
    return idx, data, new, gids_new, queries


@pytest.mark.parametrize("engine", ["fused", "vmap"])
def test_saturating_equals_fresh_static_build(idx_and_data, engine):
    """Segmented top-k == from-scratch static build on the surviving union
    (both saturate => both are the exact k-NN of the survivors)."""
    idx, data, new, gids_new, queries = idx_and_data
    k = 10
    res = idx.search(jnp.asarray(queries),
                     SearchRequest(k=k, engine=engine, **SAT))

    vecs, gids = idx._survivors()
    p = idx.params
    static = DETLSH.build(jnp.asarray(vecs), jax.random.key(7), p,
                          leaf_size=16, Nr=32)
    sres = static.search(jnp.asarray(queries),
                         SearchRequest(k=k, engine=engine, **SAT))
    static_gids = gids[np.asarray(sres.ids)]

    gt_g, gt_d = survivors_bf(idx, queries, k)
    np.testing.assert_allclose(np.asarray(res.dists), gt_d, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sres.dists), gt_d, rtol=1e-4,
                               atol=1e-4)
    for b in range(len(queries)):          # same ids up to distance ties
        assert set(np.asarray(res.ids)[b]) == set(static_gids[b]) \
            == set(gt_g[b])


@pytest.mark.parametrize("engine", ["fused", "vmap"])
def test_deleted_never_returned_before_compaction(idx_and_data, engine):
    idx, data, new, gids_new, queries = idx_and_data
    assert any(s.has_tombstones for s in idx.manifest.segments)
    res = idx.search(jnp.asarray(queries),
                     SearchRequest(k=20, engine=engine, **SAT))
    dead = set(range(40)) | set(int(g) for g in gids_new[:10])
    assert not (set(np.asarray(res.ids).ravel()) & dead)


def test_upsert_visible_immediately():
    """A point still in the delta buffer is served (exactly) right away."""
    rng = np.random.default_rng(3)
    idx, data = make_index(rng, n=300)
    probe = (data[0] + 50.0).astype(np.float32)   # far from everything
    [gid] = idx.upsert(probe)
    assert idx.memtable.n_live == 1               # not sealed yet
    res = idx.search(jnp.asarray(probe[None, :]),
                     SearchRequest(k=1, r_min=1.0))
    assert int(np.asarray(res.ids)[0, 0]) == int(gid)
    assert float(np.asarray(res.dists)[0, 0]) < 1e-3


def test_upsert_overwrites_existing_gid():
    rng = np.random.default_rng(4)
    idx, data = make_index(rng, n=300)
    moved = (data[5] + 100.0).astype(np.float32)
    idx.upsert(moved, gids=[5])
    assert idx.n_live == 300                      # moved, not added
    res = idx.search(jnp.asarray(moved[None, :]), SearchRequest(k=1, **SAT))
    assert int(np.asarray(res.ids)[0, 0]) == 5
    assert float(np.asarray(res.dists)[0, 0]) < 1e-3
    # the old location must not resurface near its former coordinates
    res_old = idx.search(jnp.asarray(data[5][None, :]),
                         SearchRequest(k=300, **SAT))
    old_ids = np.asarray(res_old.ids)[0]
    old_d = np.asarray(res_old.dists)[0]
    assert old_d[old_ids == 5] > 90.0


def test_seal_fixed_shape_and_locator():
    rng = np.random.default_rng(5)
    idx, data = make_index(rng, n=200, delta_capacity=32)
    new = make_clustered(rng, 70, D)
    gids = idx.upsert(new)                        # 2 seals + 6 in delta
    sealed = idx.manifest.segments[1:]
    assert [s.m for s in sealed] == [32, 32]
    assert idx.memtable.count == 6
    for g in gids:
        where, pos = idx.locator[int(g)]
        if where == "delta":
            assert idx.memtable.gids[pos] == g
        else:
            seg = idx._segment(where)
            assert seg.gids[pos] == g


def test_compaction_merges_sorted_and_drops_tombstones():
    rng = np.random.default_rng(6)
    idx, data = make_index(rng, n=200, delta_capacity=32, max_segments=1)
    gids = idx.upsert(make_clustered(rng, 64, D))
    idx.delete(gids[:16])
    idx.delete(np.arange(10))
    n_live = idx.n_live
    assert idx.compact()
    [seg] = idx.manifest.segments
    assert seg.m == n_live - idx.memtable.n_live
    assert not seg.has_tombstones
    assert idx.n_live == n_live
    # merged per-tree arrays really are key-sorted (the merge invariant)
    for l in range(seg.forest.L):
        valid = np.asarray(seg.forest.valid[l])
        codes = np.asarray(seg.forest.codes_sorted[l])[valid]
        keys = interleave_keys64(codes, seg.forest.K)
        assert np.all(np.diff(keys.astype(np.int64)) >= 0)
    # dropped gids are really gone
    assert not (set(seg.gids.tolist()) & set(int(g) for g in gids[:16]))


def test_stable_merge_positions_is_a_permutation():
    rng = np.random.default_rng(7)
    for _ in range(20):
        a = np.sort(rng.integers(0, 40, rng.integers(0, 30)).astype(np.uint64), kind="stable")
        b = np.sort(rng.integers(0, 40, rng.integers(0, 30)).astype(np.uint64), kind="stable")
        pa, pb = stable_merge_positions(a, b)
        merged = np.empty(len(a) + len(b), np.uint64)
        merged[pa] = a
        merged[pb] = b
        ref = np.sort(np.concatenate([a, b]), kind="stable")
        np.testing.assert_array_equal(merged, ref)


def test_merge_segments_equals_survivor_union():
    """Compacted forest == a frozen-breakpoint rebuild of the survivors:
    same leaf summaries and the same (id, code) multiset per tree."""
    rng = np.random.default_rng(8)
    idx, _ = make_index(rng, n=120, delta_capacity=32, leaf_size=8)
    gids = idx.upsert(make_clustered(rng, 64, D))
    idx.delete(gids[5:25])
    segs = idx.manifest.segments
    merged = merge_segments(segs, leaf_size=8, seg_id=99)
    from repro.streaming.segment import build_segment
    vecs, sg = idx._survivors()
    mt_live = idx.memtable.n_live
    assert merged.m == idx.n_live - mt_live
    rebuilt = build_segment(jnp.asarray(vecs[:merged.m]), sg[:merged.m],
                            idx.A, idx.params, idx.bp_all, Nr=idx.Nr,
                            leaf_size=8, seg_id=100)
    for l in range(merged.forest.L):
        va, vb = (np.asarray(merged.forest.valid[l]),
                  np.asarray(rebuilt.forest.valid[l]))
        ka = interleave_keys64(
            np.asarray(merged.forest.codes_sorted[l])[va], merged.forest.K)
        kb = interleave_keys64(
            np.asarray(rebuilt.forest.codes_sorted[l])[vb], merged.forest.K)
        np.testing.assert_array_equal(ka, kb)      # same sorted key sequence
        ga = merged.gids[np.asarray(merged.forest.point_ids[l])[va]]
        gb = rebuilt.gids[np.asarray(rebuilt.forest.point_ids[l])[vb]]
        np.testing.assert_array_equal(np.sort(ga, kind="stable"), np.sort(gb, kind="stable"))


def test_clip_fraction_and_requantile():
    rng = np.random.default_rng(9)
    idx, data = make_index(rng, n=300, delta_capacity=32)
    assert idx.clip_fraction() == 0.0             # base covers itself
    far = (make_clustered(rng, 64, D) * 20.0).astype(np.float32)
    idx.upsert(far)                               # way outside the quantiles
    assert idx.clip_fraction() > 0.0
    n_live = idx.n_live
    idx.requantile(jax.random.key(1))
    assert idx.clip_fraction() == 0.0
    assert idx.n_live == n_live
    assert len(idx.manifest.segments) == 1
    res = idx.search(jnp.asarray(far[:2]), SearchRequest(k=1, **SAT))
    assert float(np.asarray(res.dists)[0, 0]) < 1e-3


def test_gid_exhaustion_raises_clean_and_capacity_grows():
    """Exhausting the gid space must raise *before* mutating any state, and
    grow_id_capacity() must actually unblock further upserts."""
    rng = np.random.default_rng(12)
    idx, data = make_index(rng, n=64, delta_capacity=8, id_capacity=80)
    next_before = idx.next_gid
    n_live = idx.n_live
    with pytest.raises(ValueError, match="gid space exhausted"):
        idx.upsert(make_clustered(rng, 20, D))
    assert idx.next_gid == next_before and idx.n_live == n_live
    idx.grow_id_capacity(256)
    gids = idx.upsert(make_clustered(rng, 20, D))
    res = idx.search(jnp.asarray(data[:2]),
                     SearchRequest(k=idx.n_live, **SAT))
    assert set(int(g) for g in gids) <= set(np.asarray(res.ids).ravel())
    with pytest.raises(ValueError, match="shrink"):
        idx.grow_id_capacity(10)


def test_upsert_rejects_negative_gids_and_dedups_within_call():
    rng = np.random.default_rng(13)
    idx, data = make_index(rng, n=64, delta_capacity=8)
    with pytest.raises(ValueError, match="non-negative"):
        idx.upsert(np.zeros((1, D), np.float32), gids=[-1])
    assert idx.n_live == 64                       # nothing mutated
    # duplicate gid within one call: last write wins, no ghost duplicate
    v1 = np.full((1, D), 1.0, np.float32)
    v2 = np.full((1, D), 2.0, np.float32)
    idx.upsert(np.concatenate([v1, v2]), gids=[999, 999])
    assert idx.n_live == 65
    res = idx.search(jnp.asarray(v2), SearchRequest(k=2, **SAT))
    assert int(np.asarray(res.ids)[0, 0]) == 999
    assert float(np.asarray(res.dists)[0, 0]) < 1e-4
    assert int(np.asarray(res.ids)[0, 1]) != 999  # old row really gone


def test_pad_lanes_admit_nothing_from_delta():
    """The pad-lane contract holds for the streaming index's delta tier
    too: lanes >= n_active see zero candidates from any source."""
    rng = np.random.default_rng(14)
    idx, data = make_index(rng, n=128, delta_capacity=32)
    idx.upsert(make_clustered(rng, 5, D))         # non-empty memtable
    qs = np.concatenate([data[:2], np.zeros((3, D), np.float32)])
    for engine in ("fused", "vmap"):
        res = idx.search(jnp.asarray(qs),
                         SearchRequest(k=4, engine=engine, n_active=2,
                                       r_min=1.0))
        assert np.all(np.asarray(res.stats.n_candidates)[2:] == 0), engine
        assert np.all(np.asarray(res.ids)[2:] == idx.id_capacity), engine


def test_recall_parity_with_static_at_default_radius():
    """Sanity at a realistic (non-saturating) radius: the segmented index's
    recall stays close to a static build over the same live set."""
    rng = np.random.default_rng(10)
    idx, data = make_index(rng, n=500, delta_capacity=64, max_segments=1)
    idx.upsert(make_clustered(rng, 128, D))
    idx.compact()
    queries = make_clustered(rng, 8, D)
    vecs, gids = idx._survivors()
    k = 10
    gt_g, _ = survivors_bf(idx, queries, k)
    static = DETLSH.build(jnp.asarray(vecs), jax.random.key(2), idx.params,
                          leaf_size=16, Nr=32)

    ids_s = np.asarray(
        idx.search(jnp.asarray(queries), SearchRequest(k=k)).ids)
    ids_f = gids[np.asarray(
        static.search(jnp.asarray(queries), SearchRequest(k=k)).ids)]
    rec = {"stream": np.mean([len(set(ids_s[i]) & set(gt_g[i])) / k
                              for i in range(len(queries))]),
           "static": np.mean([len(set(ids_f[i]) & set(gt_g[i])) / k
                              for i in range(len(queries))])}
    assert rec["stream"] >= rec["static"] - 0.15, rec
    assert rec["stream"] >= 0.5, rec
