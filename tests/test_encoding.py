"""Tests for dynamic encoding: breakpoint selection + iSAX encoding."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import encoding as enc


def _equi_depth_error(coords, bp):
    """Max deviation of per-bucket occupancy from n/Nr, as a fraction."""
    n, D = coords.shape
    Nr = bp.shape[1] - 1
    errs = []
    for d in range(D):
        counts, _ = np.histogram(coords[:, d], bins=np.asarray(bp[d]))
        errs.append(np.abs(counts - n / Nr).max() / (n / Nr))
    return max(errs)


def test_full_sort_breakpoints_are_equi_depth():
    rng = np.random.default_rng(1)
    coords = rng.standard_normal((8192, 3)).astype(np.float32)
    bp = np.asarray(enc.select_breakpoints(jnp.asarray(coords), 64,
                                           method="full_sort"))
    assert bp.shape == (3, 65)
    assert _equi_depth_error(coords, bp) < 0.25


def test_sample_sort_breakpoints_cover_and_balance():
    rng = np.random.default_rng(2)
    coords = np.concatenate([rng.standard_normal((20000, 2)),
                             5 + 2 * rng.standard_normal((20000, 2))],
                            axis=0).astype(np.float32)  # bimodal
    bp = np.asarray(enc.select_breakpoints(
        jnp.asarray(coords), 256, method="sample_sort",
        key=jax.random.key(0), sample_fraction=0.1))
    # endpoints must cover the full data range
    assert np.all(bp[:, 0] <= coords.min(0) + 1e-6)
    assert np.all(bp[:, -1] >= coords.max(0) - 1e-6)
    assert np.all(np.diff(bp, axis=1) >= -1e-7)  # monotone
    # sample-level accuracy: ~n_s/Nr = 15 samples per bucket -> max deviation
    # over 512 buckets is a few sigma of 1/sqrt(15)
    assert _equi_depth_error(coords, bp) < 1.6


def test_histogram_refine_converges_to_equi_depth():
    rng = np.random.default_rng(3)
    # heavy-tailed + shifted — hard case for uniform binning
    coords = (rng.standard_t(3, size=(30000, 2)) + 2).astype(np.float32)
    bp = np.asarray(enc.breakpoints_histogram_refine(jnp.asarray(coords), 64,
                                                     rounds=8))
    assert _equi_depth_error(coords, bp) < 0.35
    # more rounds must not be worse (convergence)
    bp12 = np.asarray(enc.breakpoints_histogram_refine(jnp.asarray(coords), 64,
                                                       rounds=12))
    assert _equi_depth_error(coords, bp12) <= _equi_depth_error(coords, bp) + 0.05


def test_encode_region_bracket_invariant():
    """B[d, b] <= x <= B[d, b+1] for the assigned region b (Alg. 1 line 7)."""
    rng = np.random.default_rng(4)
    coords = rng.standard_normal((4096, 5)).astype(np.float32)
    bp = enc.select_breakpoints(jnp.asarray(coords), 32, method="full_sort")
    codes = np.asarray(enc.encode(jnp.asarray(coords), bp))
    bp = np.asarray(bp)
    assert codes.min() >= 0 and codes.max() <= 31
    for d in range(5):
        lo = bp[d][codes[:, d]]
        hi = bp[d][codes[:, d] + 1]
        eps = 1e-5
        assert np.all(coords[:, d] >= lo - eps)
        assert np.all(coords[:, d] <= hi + eps)


def test_encode_monotone_in_coordinate():
    """Larger coordinate never gets a smaller region id (order preserving)."""
    rng = np.random.default_rng(5)
    coords = rng.standard_normal((2048, 1)).astype(np.float32)
    bp = enc.select_breakpoints(jnp.asarray(coords), 256, method="full_sort")
    codes = np.asarray(enc.encode(jnp.asarray(coords), bp))[:, 0]
    order = np.argsort(coords[:, 0], kind="stable")
    assert np.all(np.diff(codes[order]) >= 0)


def test_distributed_equivalence_of_histogram_counts():
    """Counts over shards sum to global counts — the psum invariant that
    makes multi-pod global breakpoints exact."""
    rng = np.random.default_rng(6)
    coords = rng.standard_normal((4000, 3)).astype(np.float32)
    edges = enc.select_breakpoints(jnp.asarray(coords), 16, method="full_sort")
    full = np.asarray(enc.histogram_counts(jnp.asarray(coords), edges))
    parts = sum(
        np.asarray(enc.histogram_counts(jnp.asarray(coords[i::4]), edges))
        for i in range(4))
    np.testing.assert_array_equal(full, parts)
    assert full.sum() == 4000 * 3


@settings(max_examples=25, deadline=None)
@given(st.integers(8, 64), st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_property_encode_bracket_random(nr, d, seed):
    """Property: encode() always lands coords inside their region bracket."""
    rng = np.random.default_rng(seed)
    n = 512
    coords = (rng.standard_normal((n, d)) * rng.uniform(0.1, 10)).astype(
        np.float32)
    bp = enc.select_breakpoints(jnp.asarray(coords), nr, method="full_sort")
    codes = np.asarray(enc.encode(jnp.asarray(coords), bp))
    bpn = np.asarray(bp)
    for j in range(d):
        lo = bpn[j][codes[:, j]]
        hi = bpn[j][codes[:, j] + 1]
        tol = 1e-5 * max(1.0, np.abs(coords[:, j]).max())
        assert np.all(coords[:, j] >= lo - tol)
        assert np.all(coords[:, j] <= hi + tol)
