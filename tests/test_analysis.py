"""Tests for the jaxlint static analyzer (``repro.analysis``).

Corpus protocol: every known-bad fixture line carries an
``# EXPECT: rule[, rule...]`` marker, and the corpus test asserts the
analyzer reports EXACTLY that (line, rule) set per file — so the bad corpus
also proves the analyzer does not over-report.  The known-good corpus must
produce zero findings.  The meta-test asserts the real tree is clean.
"""

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (ALL_RULES, SourceFile, format_human,
                            load_project, run_rules)

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "jaxlint"
BAD = FIXTURES / "bad"
GOOD = FIXTURES / "good"

_EXPECT_RE = re.compile(r"#\s*EXPECT:\s*([a-z\-]+(?:\s*,\s*[a-z\-]+)*)")


def _expected_findings(path: Path) -> set[tuple[int, str]]:
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), start=1):
        m = _EXPECT_RE.search(line)
        if m:
            for rule in m.group(1).split(","):
                out.add((i, rule.strip()))
    return out


def _run_dir(path: Path):
    return run_rules(load_project([path]), ALL_RULES)


def _cli(*args: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=REPO, env=env)


# ---------------------------------------------------------------------------
# Corpus tests
# ---------------------------------------------------------------------------

BAD_FILES = sorted(p for p in BAD.rglob("*.py"))
GOOD_FILES = sorted(p for p in GOOD.rglob("*.py"))


def test_corpus_exists():
    # Tentpole acceptance: >= 5 distinct rule classes, each with bad AND
    # good fixtures.
    assert len(BAD_FILES) >= 5 and len(GOOD_FILES) >= 5
    expected_rules = set()
    for p in BAD_FILES:
        expected_rules.update(r for _, r in _expected_findings(p))
    assert len(expected_rules) >= 8, expected_rules


@pytest.mark.parametrize("path", BAD_FILES, ids=lambda p: p.name)
def test_bad_fixture_flags_exactly_expected(path):
    report = _run_dir(BAD)
    rel = str(path.relative_to(REPO))
    got = {(f.line, f.rule) for f in report.findings if f.path == rel}
    want = _expected_findings(path)
    assert want, f"{path} has no EXPECT markers"
    assert got == want, (
        f"{rel}: findings != EXPECT markers\n  extra: {sorted(got - want)}"
        f"\n  missing: {sorted(want - got)}")


def test_good_corpus_is_clean():
    report = _run_dir(GOOD)
    assert report.findings == (), format_human(report)


def test_findings_have_file_line_anchors():
    report = _run_dir(BAD)
    assert report.findings
    for f in report.findings:
        assert f.anchor == f"{f.path}:{f.line}:{f.col}"
        assert f.line >= 1 and f.col >= 0
        assert f.path.startswith("tests/fixtures/jaxlint/bad"), f.path
        # The anchored line really exists in the file.
        text = (REPO / f.path).read_text().splitlines()
        assert f.line <= len(text)


def test_rule_battery_metadata():
    names = [r.name for r in ALL_RULES]
    assert len(names) == len(set(names))
    for r in ALL_RULES:
        assert r.code.startswith("JX")
        assert r.severity in ("error", "warning")
        assert r.doc


# ---------------------------------------------------------------------------
# Suppression mechanics
# ---------------------------------------------------------------------------

# Built by concatenation so the analyzer's line-based suppression scanner
# never sees a directive in THIS file's raw source when it walks tests/.
_DIRECTIVE = "# jax" + "lint: disable="


def _parse(tmp_path, text):
    p = tmp_path / "x.py"
    p.write_text(text)
    return SourceFile(p, "x.py", None)


def test_justified_suppression_suppresses(tmp_path):
    src = _parse(tmp_path,
                 "import numpy as np\n"
                 f"o = np.argsort(v)  {_DIRECTIVE}unstable-sort"
                 " -- permutation unused\n")
    assert src.suppressed("unstable-sort", 2)
    assert not src.suppressed("trace-np-call", 2)


def test_unjustified_suppression_is_inert(tmp_path):
    src = _parse(tmp_path,
                 "import numpy as np\n"
                 f"o = np.argsort(v)  {_DIRECTIVE}unstable-sort\n")
    assert not src.suppressed("unstable-sort", 2)


def test_comment_line_suppression_governs_next_code_line(tmp_path):
    src = _parse(tmp_path,
                 f"{_DIRECTIVE}unstable-sort -- values only\n"
                 "#   (continued)\n"
                 "o = np.argsort(v)\n")
    assert src.suppressed("unstable-sort", 3)


def test_disable_all(tmp_path):
    src = _parse(tmp_path,
                 f"o = np.argsort(v)  {_DIRECTIVE}all -- generated\n")
    assert src.suppressed("unstable-sort", 1)
    assert src.suppressed("narrow-arith", 1)


def test_suppression_findings_reported():
    report = _run_dir(BAD)
    rules = {f.rule for f in report.findings
             if "suppression_bad" in f.path}
    assert "suppression" in rules          # unjustified + unknown-rule
    assert "unstable-sort" in rules        # the inert suppression suppressed nothing


# ---------------------------------------------------------------------------
# CLI / exit codes
# ---------------------------------------------------------------------------

def test_cli_exits_nonzero_on_bad_corpus():
    r = _cli("tests/fixtures/jaxlint/bad")
    assert r.returncode == 1, r.stdout + r.stderr
    assert re.search(r"bad/\w+\.py:\d+:\d+: error", r.stdout)


def test_cli_exits_zero_on_repo_tree():
    # Meta-test: the real tree must stay jaxlint-clean.
    r = _cli("src", "tests")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 error(s)" in r.stdout


def test_cli_json_output():
    r = _cli("--json", "tests/fixtures/jaxlint/bad")
    assert r.returncode == 1
    payload = json.loads(r.stdout)
    assert payload["errors"] > 0
    assert {"rule", "severity", "path", "line", "col", "message"} <= set(
        payload["findings"][0])


def test_cli_list_rules():
    r = _cli("--list-rules")
    assert r.returncode == 0
    assert "unstable-sort" in r.stdout and "JX201" in r.stdout


def test_cli_select_single_rule():
    r = _cli("--select", "unstable-sort", "tests/fixtures/jaxlint/bad")
    assert r.returncode == 1
    assert "unstable-sort" in r.stdout
    assert "narrow-arith" not in r.stdout


def test_fixture_corpus_pruned_from_directory_walks():
    # Walking tests/ must not flag the known-bad corpus (sentinel pruning),
    # which is exactly why test_cli_exits_zero_on_repo_tree can pass.
    report = run_rules(load_project([REPO / "tests"]), ALL_RULES)
    assert not any("fixtures/jaxlint" in f.path for f in report.findings)
