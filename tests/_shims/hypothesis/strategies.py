"""Strategy objects for the hypothesis shim (see package docstring).

Each strategy exposes ``example(rng) -> value`` drawing one pseudo-random
value from a ``numpy.random.Generator``.  Bounds are inclusive, matching
real hypothesis semantics for ``integers``/``floats``.
"""

from __future__ import annotations


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            from . import _Unsatisfied
            for _ in range(100):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise _Unsatisfied()

        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    assert min_value <= max_value
    # Mix boundary values in (real hypothesis is heavily boundary-biased).
    def draw(rng):
        if rng.random() < 0.1:
            return int(rng.choice([min_value, max_value]))
        return int(rng.integers(min_value, max_value + 1))

    return SearchStrategy(draw)


def floats(min_value: float, max_value: float, **_kw) -> SearchStrategy:
    assert min_value <= max_value
    def draw(rng):
        if rng.random() < 0.1:
            return float(rng.choice([min_value, max_value]))
        return float(min_value + (max_value - min_value) * rng.random())

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def sampled_from(seq) -> SearchStrategy:
    seq = list(seq)
    return SearchStrategy(lambda rng: seq[int(rng.integers(0, len(seq)))])


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: int | None = None, unique: bool = False
          ) -> SearchStrategy:
    max_size = max_size if max_size is not None else min_size + 10

    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        out = []
        tries = 0
        while len(out) < size and tries < size * 50 + 50:
            tries += 1
            v = elements.example(rng)
            if unique and v in out:
                continue
            out.append(v)
        return out

    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.example(rng) for s in strategies))
