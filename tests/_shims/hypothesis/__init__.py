"""Minimal, dependency-free stand-in for the ``hypothesis`` package.

Only loaded when the real ``hypothesis`` distribution is not installed (see
``tests/conftest.py``: the shim directory is appended to ``sys.path`` behind
an ``import hypothesis`` guard, so a real install always wins).

Implements the subset this repo's property tests use:

  * ``@given(*strategies)`` — draws ``max_examples`` pseudo-random examples
    from each strategy and calls the test once per example;
  * ``@settings(max_examples=..., deadline=...)`` — composes with ``given``
    in either decorator order;
  * ``assume(cond)`` — skips the current example;
  * strategies: ``integers``, ``floats``, ``booleans``, ``sampled_from``,
    ``lists``, ``tuples``, ``just``.

Example generation is deterministic: the RNG is seeded from the test's
qualified name, so failures reproduce across runs.  Shrinking, the example
database, and health checks are intentionally not implemented — on failure
the offending example is attached to the raised exception instead.
"""

from __future__ import annotations

import functools
import inspect
import zlib


class _Unsatisfied(Exception):
    """Raised by assume(False) — the example is discarded, not failed."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class HealthCheck:  # accepted and ignored (API compatibility)
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


_DEFAULT_MAX_EXAMPLES = 100


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    """Decorator recording run settings; order-independent wrt ``given``."""

    def deco(f):
        f._shim_settings = {"max_examples": max_examples}
        return f

    return deco


def given(*strategies, **kw_strategies):
    def deco(f):
        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_shim_settings", None) or getattr(
                f, "_shim_settings", {"max_examples": _DEFAULT_MAX_EXAMPLES})
            max_examples = conf["max_examples"]
            seed = zlib.crc32(
                f"{f.__module__}.{f.__qualname__}".encode()) & 0x7FFFFFFF
            import numpy as np
            rng = np.random.default_rng(seed)
            produced = 0
            attempts = 0
            while produced < max_examples and attempts < max_examples * 20:
                attempts += 1
                ex_args = tuple(s.example(rng) for s in strategies)
                ex_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    f(*args, *ex_args, **kwargs, **ex_kw)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    e.args = (f"{e.args[0] if e.args else e!r}\n"
                              f"[hypothesis-shim] falsifying example: "
                              f"args={ex_args!r} kwargs={ex_kw!r}",
                              *e.args[1:])
                    raise
                produced += 1
            return None

        # Strategy-bound params fill the *rightmost* positions (hypothesis
        # semantics).  Hide them from the exposed signature so pytest does
        # not look for same-named fixtures; leading params stay visible and
        # keep working as fixtures.
        params = list(inspect.signature(f).parameters.values())
        n_bound = len(strategies)
        keep = params[:len(params) - n_bound]
        keep = [p for p in keep if p.name not in kw_strategies]
        wrapper.__signature__ = inspect.Signature(keep)
        del wrapper.__wrapped__
        return wrapper

    return deco


from . import strategies  # noqa: E402,F401

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]
