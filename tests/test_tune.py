"""repro.tune: the recall-targeted auto-tuner (docs/DESIGN.md §11).

Contracts under test:

  * ``suggest_params`` returns a ``TuneResult`` whose spec is a plain,
    buildable ``IndexSpec`` with the winning probe depth baked in, whose
    trials are ``repro.eval.pareto.CurvePoint``s (one per grid config x
    probe depth, probe depths sharing a build), and whose selection is
    the least-work trial among those meeting the target;
  * ``achieved`` is honest: True implies the winner's measured recall met
    the target, False returns the best-recall config anyway;
  * ``TuneResult.request()`` reproduces the winning measurement and
    ``to_dict()`` is JSON-clean (the BENCH_tune.json payload);
  * ``repro.tune.tune`` (also exported as ``repro.api.tune``) goes
    target_recall -> built full-size index in one call;
  * the grid and targets validate eagerly.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.eval.pareto import CurvePoint
from repro.tune import (DEFAULT_GRID, TuneResult, predicted_build_cost,
                        suggest_params, tune)
from tests.conftest import make_clustered

GRID = dict(Ks=(4,), Ls=(2, 3), betas=(0.1,), probe_depths=(0, 2))


@pytest.fixture(scope="module")
def tuned():
    rng = np.random.default_rng(17)
    sample = jnp.asarray(make_clustered(rng, 1024, 16))
    result = suggest_params(sample, 0.7, key=jax.random.PRNGKey(2), k=5,
                            n_queries=16, max_rounds=32, repeat=1, **GRID)
    return sample, result


def test_suggest_params_result_shape(tuned):
    sample, result = tuned
    assert isinstance(result, TuneResult)
    assert len(result.trials) == 2 * 2          # (Ls) x (probe_depths)
    assert all(isinstance(t, CurvePoint) for t in result.trials)
    assert {(t.params["L"], t.probe_depth) for t in result.trials} \
        == {(L, pd) for L in GRID["Ls"] for pd in GRID["probe_depths"]}
    assert 0.0 <= result.recall <= 1.0
    assert result.work_per_query > 0
    assert result.n_sample == 1024 and result.k == 5
    assert result.spec.L in GRID["Ls"]
    assert result.spec.probe_depth in GRID["probe_depths"]
    assert result.probe_depth == result.spec.probe_depth


def test_selection_is_least_work_meeting_target(tuned):
    _, result = tuned
    ok = [t for t in result.trials if t.recall >= result.target_recall]
    if result.achieved:
        assert result.recall >= result.target_recall
        assert ok and result.work_per_query == min(t.work_per_query
                                                   for t in ok)
    else:
        assert not ok
        assert result.recall == max(t.recall for t in result.trials)


def test_spec_is_buildable_and_request_reproduces(tuned):
    sample, result = tuned
    index = repro.api.build(sample, jax.random.PRNGKey(7), result.spec)
    req = result.request()
    assert req.k == result.k
    assert req.probe_depth == result.spec.probe_depth
    res = index.search(sample[:8], req)
    assert np.asarray(res.ids).shape == (8, result.k)
    # request(**overrides) forwards
    assert result.request(k=3).k == 3


def test_to_dict_is_json_clean(tuned):
    _, result = tuned
    d = result.to_dict()
    blob = json.loads(json.dumps(d))
    assert blob["spec"]["probe_depth"] == result.spec.probe_depth
    assert len(blob["trials"]) == len(result.trials)
    assert blob["achieved"] == result.achieved


def test_predicted_build_cost_model():
    # linear in L, increasing in K and n
    assert predicted_build_cost(1000, 4, 8) == 2 * predicted_build_cost(
        1000, 4, 4)
    assert predicted_build_cost(1000, 8, 4) > predicted_build_cost(1000, 4, 4)
    assert predicted_build_cost(2000, 4, 4) > predicted_build_cost(1000, 4, 4)


def test_validation():
    sample = jnp.zeros((32, 4))
    with pytest.raises(ValueError, match="target_recall"):
        suggest_params(sample, 0.0)
    with pytest.raises(ValueError, match="target_recall"):
        suggest_params(sample, 1.5)
    with pytest.raises(ValueError, match="grid"):
        suggest_params(sample, 0.9, Ls=())
    with pytest.raises(ValueError):
        suggest_params(sample, 0.9, k=0)
    assert "Ks" in DEFAULT_GRID and DEFAULT_GRID["probe_depths"][0] == 0


def test_tune_builds_full_index():
    rng = np.random.default_rng(23)
    data = jnp.asarray(make_clustered(rng, 2048, 16))
    index, result = tune(data, jax.random.PRNGKey(4), 0.7, sample_size=512,
                         k=5, max_rounds=32, repeat=1, **GRID)
    assert index.n_points == 2048            # built on the FULL data
    assert result.n_sample == 512            # tuned on the sample
    # predicted cost extrapolates to the full n, not the sample
    assert result.predicted_build_cost == predicted_build_cost(
        2048, result.spec.K, result.spec.L)
    res = index.search(data[:8], result.request())
    assert np.asarray(res.ids).shape == (8, 5)
    assert repro.api.tune is tune            # the api-surface alias


def test_probe_depths_share_a_build(tuned):
    """Trials at the same (K, L, beta) report the same build_seconds —
    the build is done once and every probe depth is a request-time knob."""
    _, result = tuned
    by_cfg = {}
    for t in result.trials:
        by_cfg.setdefault((t.params["K"], t.params["L"], t.params["beta"]),
                          set()).add(t.build_seconds)
    assert all(len(v) == 1 for v in by_cfg.values())
