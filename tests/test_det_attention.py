"""Seed DET-LSH decode attention vs exact attention (oracle path).

The seed path is deprecated (repro.decode is the production subsystem,
docs/DESIGN.md §10) but kept as the bit-level oracle; these tests pin its
behavior.  pyproject turns the shim warnings into errors, so every seed
call here goes through ``_seed`` / an explicit ``pytest.warns``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import det_attention as DA
from repro.models import layers as L

_shim = pytest.mark.filterwarnings(
    "ignore:.*is deprecated. use.*:DeprecationWarning")


def _mk(rng, b=2, S=512, hk=2, g=2, dh=32, peaky=True):
    h = hk * g
    k_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(
        np.float32) * 0.3)
    v_cache = jnp.asarray(rng.standard_normal((b, S, hk, dh)).astype(
        np.float32))
    if peaky:
        # plant strong matches: queries aligned with a few specific keys
        q = np.asarray(k_cache[:, 123, :, :])            # (b, hk, dh)
        q = np.repeat(q[:, :, None, :], g, axis=2) * 16.0
        q = q + 0.05 * rng.standard_normal(q.shape).astype(np.float32)
        q = jnp.asarray(q.reshape(b, 1, h, dh))
    else:
        q = jnp.asarray(rng.standard_normal((b, 1, h, dh)).astype(
            np.float32))
    return q, k_cache, v_cache


def test_mips_augmentation_monotone(rng):
    keys = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    aug, R = DA._augment_keys(keys)
    norms = np.asarray(jnp.sum(aug ** 2, -1))
    np.testing.assert_allclose(norms, norms[0] * np.ones_like(norms),
                               rtol=1e-4)  # all equal to R^2
    q = jnp.asarray(rng.standard_normal(8).astype(np.float32))
    qa = jnp.concatenate([q, jnp.zeros(1)])
    d2 = jnp.sum((aug - qa[None]) ** 2, -1)
    ip = keys @ q
    # distances and inner products must be inversely rank-correlated
    assert np.all(np.argsort(np.asarray(d2), kind="stable") == np.argsort(-np.asarray(ip), kind="stable"))


def test_seed_shims_warn_with_migration_target(rng):
    q, k_cache, v_cache = _mk(rng, b=1, S=128, hk=1, g=1, dh=16,
                              peaky=False)
    with pytest.warns(DeprecationWarning,
                      match=r"build_kv_index is deprecated. use "
                            r"repro.decode.KVCacheIndex.prefill"):
        idx = DA.build_kv_index(k_cache, jax.random.key(0), leaf_size=8)
    with pytest.warns(DeprecationWarning,
                      match=r"det_decode_attention is deprecated. use "
                            r"repro.decode.LSHDecoder"):
        DA.det_decode_attention(q, k_cache, v_cache, idx, 128,
                                m_leaves=4, window=8, sinks=2)


@_shim
def test_seed_shim_validates_like_kvspec(rng):
    # satellite 6: layout knobs route through IndexSpec's eager validation
    _, k_cache, _ = _mk(rng, b=1, S=128, hk=1, g=1, dh=16, peaky=False)
    with pytest.raises(ValueError, match="Nr"):
        DA.build_kv_index(k_cache, jax.random.key(0), Nr=300)
    with pytest.raises(ValueError, match="leaf_size"):
        DA.build_kv_index(k_cache, jax.random.key(0), leaf_size=0)


@_shim
def test_retrieval_finds_planted_match(rng):
    q, k_cache, v_cache = _mk(rng)
    idx = DA.build_kv_index(k_cache, jax.random.key(0))
    b, _, h, dh = q.shape
    hk = k_cache.shape[2]
    qh = q.reshape(b, hk, h // hk, dh)
    ids = np.asarray(DA.retrieve_topm(idx, qh, m_leaves=16))
    # the planted position 123 must appear in the candidates
    hit = (ids == 123).any(axis=-1)
    assert hit.mean() >= 0.75, hit.mean()


@_shim
def test_det_attention_close_to_exact_on_peaky(rng):
    q, k_cache, v_cache = _mk(rng)
    S = k_cache.shape[1]
    idx = DA.build_kv_index(k_cache, jax.random.key(0))
    out_det = DA.det_decode_attention(q, k_cache, v_cache, idx, S,
                                      m_leaves=16, window=32, sinks=4)
    out_full = L.decode_gqa_attention(q, k_cache, v_cache, S)
    a = np.asarray(out_det).reshape(-1, q.shape[-1])
    b_ = np.asarray(out_full).reshape(-1, q.shape[-1])
    cos = np.sum(a * b_, -1) / (np.linalg.norm(a, axis=-1)
                                * np.linalg.norm(b_, axis=-1) + 1e-9)
    assert cos.mean() > 0.97, cos


@_shim
def test_det_attention_respects_length_mask(rng):
    q, k_cache, v_cache = _mk(rng, peaky=False)
    idx = DA.build_kv_index(k_cache, jax.random.key(0))
    out = DA.det_decode_attention(q, k_cache, v_cache, idx, 200,
                                  m_leaves=8, window=16, sinks=2)
    assert np.isfinite(np.asarray(out)).all()
