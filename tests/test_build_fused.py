"""Fused single-sort build pipeline: bit-identity with the seed builder.

The contract (docs/DESIGN.md §8): the fused pipeline (encode + key-pack
kernel, ONE stable variadic sort for all L trees, vectorized assembly) must
produce *bit-identical* forests to the seed per-tree double-argsort path
(``build_impl='reference'``), on every builder entry point — static
(``build_forest``/``DETLSH``), streaming seal (``build_segment``), and the
PDET per-shard build — and loaded snapshots must answer searches
bit-identically regardless of which builder wrote them.

The hypothesis property pins the heart of it: the stable lexicographic
(hi, lo)-word sort induces the same permutation — hence identical leaf
grouping (lo/hi/valid summaries and per-leaf member sets) — as the seed's
stable argsort-by-lo-then-argsort-by-hi composition, across random
n/K/leaf_size.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.api
from repro.core import DETLSH, derive_params
from repro.core.detree import (CODE_DTYPE, LEAF_DTYPE, assemble_sorted_forest,
                               build_forest, code_sort_orders,
                               interleave_keys, _sort_by_code)
from tests.conftest import make_clustered

_FOREST_KEYS = ("point_ids", "proj_sorted", "codes_sorted", "valid",
                "leaf_lo", "leaf_hi", "leaf_valid", "breakpoints")


def _assert_forests_equal(a, b, msg=""):
    assert a.n == b.n and a.leaf_size == b.leaf_size
    for k in _FOREST_KEYS:
        xa, xb = np.asarray(getattr(a, k)), np.asarray(getattr(b, k))
        assert xa.dtype == xb.dtype, (k, xa.dtype, xb.dtype)
        np.testing.assert_array_equal(xa, xb, err_msg=f"{msg}{k}")


def _rand_proj(rng, n, D):
    return jnp.asarray((rng.standard_normal((n, D)) * 2.0)
                       .astype(np.float32))


# ---------------------------------------------------------------------------
# Forest bit-identity: fused == reference, all impls
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["auto", "xla", "pallas_interpret"])
@pytest.mark.parametrize("n,K,L,leaf_size",
                         [(1000, 4, 3, 32), (513, 8, 2, 16),
                          (129, 16, 1, 8), (300, 5, 4, 8)])
def test_fused_build_bit_identical_to_reference(rng, impl, n, K, L,
                                                leaf_size):
    proj = _rand_proj(rng, n, L * K)
    ref = build_forest(proj, K, L, Nr=64, leaf_size=leaf_size,
                       breakpoint_method="full_sort",
                       build_impl="reference")
    got = build_forest(proj, K, L, Nr=64, leaf_size=leaf_size,
                       breakpoint_method="full_sort", build_impl=impl,
                       build_chunk=128)
    _assert_forests_equal(ref, got, msg=f"impl={impl} ")


def test_narrow_storage_dtypes_and_size_bytes(rng):
    proj = _rand_proj(rng, 512, 8)
    f = build_forest(proj, 4, 2, Nr=64, leaf_size=16)
    assert f.codes_sorted.dtype == CODE_DTYPE
    assert f.leaf_lo.dtype == LEAF_DTYPE and f.leaf_hi.dtype == LEAF_DTYPE
    assert f.valid.dtype == jnp.bool_ and f.leaf_valid.dtype == jnp.bool_
    # size_bytes reports the actual resident bytes of the code-side arrays.
    want = sum(np.asarray(getattr(f, k)).nbytes
               for k in ("codes_sorted", "point_ids", "leaf_lo", "leaf_hi",
                         "breakpoints"))
    assert f.size_bytes() == want


@pytest.mark.parametrize("K", [1, 2, 4, 5, 8, 9, 11, 12, 16])
def test_compactor_numpy_keys_match_detree_words(rng, K):
    """The compactor's pure-numpy uint64 keys == the device key words
    joined (same shift/mask/sum; the host merge must not diverge from the
    device sort order).  K in {9, 11, 12} exercises the word-overflow
    positions (lo_bits*K > 32) that both sides must drop identically."""
    from repro.streaming.compactor import interleave_keys64
    codes = rng.integers(0, 256, size=(3, 100, K))
    hi, lo = interleave_keys(jnp.asarray(codes, jnp.int32), K)
    want = ((np.asarray(hi).astype(np.uint64) << np.uint64(32))
            | np.asarray(lo).astype(np.uint64))
    np.testing.assert_array_equal(interleave_keys64(codes, K), want)
    np.testing.assert_array_equal(
        interleave_keys64(codes.astype(np.uint8), K), want)


def test_nr_over_256_is_rejected(rng):
    with pytest.raises(ValueError, match="uint8"):
        build_forest(_rand_proj(rng, 64, 4), 2, 2, Nr=512, leaf_size=8)
    with pytest.raises(ValueError, match="uint8"):
        repro.api.IndexSpec(Nr=512)


# ---------------------------------------------------------------------------
# Hypothesis property: single-sort permutation == seed double argsort
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(1, 220), st.integers(1, 9), st.integers(1, 12),
       st.integers(0, 2 ** 31 - 1))
def test_single_sort_matches_double_argsort_grouping(n, K, leaf_size, seed):
    """The packed-word single sort induces the same leaf grouping (identical
    lo/hi/valid summaries and per-leaf member sets) as the seed double
    argsort — here with many duplicate codes, the tie-heavy regime."""
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 5, size=(n, K)), jnp.int32)

    order_ref = np.asarray(_sort_by_code(codes, K))
    key_hi, key_lo = interleave_keys(codes[None], K)       # (1, n) words
    order_new = np.asarray(code_sort_orders(key_hi, key_lo, K))[0]

    # Both sorts are stable over the same key: identical permutations —
    # on the eager host (lexsort) path and the traced (lax.sort) path.
    np.testing.assert_array_equal(order_ref, order_new)
    order_traced = np.asarray(jax.jit(
        lambda h, lo: code_sort_orders(h, lo, K))(key_hi, key_lo))[0]
    np.testing.assert_array_equal(order_ref, order_traced)

    # And the contract that actually matters downstream — identical leaf
    # grouping — restated structurally (member sets per leaf + summaries),
    # so it keeps holding even if the sort ever becomes only
    # grouping-equivalent rather than permutation-equal.
    proj = jnp.asarray(rng.standard_normal((n, K)).astype(np.float32))
    a = assemble_sorted_forest(proj[None], codes[None],
                               jnp.asarray(order_ref)[None],
                               n=n, leaf_size=leaf_size)
    b = assemble_sorted_forest(proj[None], codes[None],
                               jnp.asarray(order_new)[None],
                               n=n, leaf_size=leaf_size)
    np.testing.assert_array_equal(np.asarray(a["leaf_lo"]),
                                  np.asarray(b["leaf_lo"]))
    np.testing.assert_array_equal(np.asarray(a["leaf_hi"]),
                                  np.asarray(b["leaf_hi"]))
    np.testing.assert_array_equal(np.asarray(a["leaf_valid"]),
                                  np.asarray(b["leaf_valid"]))
    n_leaves = -(-n // leaf_size)
    for leaf in range(n_leaves):
        sl = slice(leaf * leaf_size, (leaf + 1) * leaf_size)
        va = np.asarray(a["valid"])[0, sl]
        assert (set(np.asarray(a["point_ids"])[0, sl][va].tolist())
                == set(np.asarray(b["point_ids"])[0, sl][va].tolist()))


# ---------------------------------------------------------------------------
# Search bit-identity: old-build vs fused-build, both engines
# ---------------------------------------------------------------------------

def _search_pair(idx_a, idx_b, queries, engine, k=8):
    req = repro.api.SearchRequest(k=k, r_min=0.5, engine=engine)
    ra = idx_a.search(queries, req)
    rb = idx_b.search(queries, req)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rb.dists))


def test_search_bit_identical_old_vs_fused_build(rng):
    data = jnp.asarray(make_clustered(rng, 1024, 12))
    queries = jnp.asarray(make_clustered(rng, 16, 12))
    p = derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    old = DETLSH.build(data, jax.random.key(0), p, leaf_size=16,
                       build_impl="reference")
    new = DETLSH.build(data, jax.random.key(0), p, leaf_size=16)
    _assert_forests_equal(old.forest, new.forest)
    for engine in ("vmap", "fused"):
        _search_pair(old, new, queries, engine)


def test_streaming_seal_bit_identical_old_vs_fused(rng):
    """The one-pass fused seal (project+encode+pack in one kernel, widening
    stats from the same pass) == the seed seal path, bitwise."""
    from repro.core import encoding as enc, hashing
    from repro.streaming.segment import build_segment
    data = jnp.asarray(make_clustered(rng, 300, 10))
    extra = jnp.asarray(make_clustered(rng, 96, 10) * 1.5)
    p = derive_params(K=4, c=1.5, L=3, beta_override=0.1)
    A = hashing.sample_projections(jax.random.key(1), 10, p.K, p.L)
    bp_all = enc.select_breakpoints(hashing.project(data, A), 32)
    gids = np.arange(96, dtype=np.int64)
    old = build_segment(extra, gids, A, p, bp_all, Nr=32, leaf_size=8,
                        seg_id=0, build_impl="reference")
    new = build_segment(extra, gids, A, p, bp_all, Nr=32, leaf_size=8,
                        seg_id=0)
    _assert_forests_equal(old.forest, new.forest, msg="seal ")
    np.testing.assert_allclose(old.clip_fraction, new.clip_fraction,
                               rtol=1e-6, atol=1e-7)


def test_streaming_index_search_identical_old_vs_fused(rng):
    from repro.streaming import StreamingDETLSH
    data = make_clustered(rng, 256, 10)
    extra = make_clustered(rng, 96, 10)
    queries = jnp.asarray(make_clustered(rng, 8, 10))
    p = derive_params(K=4, c=1.5, L=2, beta_override=0.1)
    built = {}
    for impl in ("reference", "auto"):
        idx = StreamingDETLSH.build(jnp.asarray(data), jax.random.key(2), p,
                                    leaf_size=16, delta_capacity=32,
                                    build_impl=impl)
        gids = idx.upsert(extra)
        idx.delete(gids[:10])
        built[impl] = idx
    for engine in ("vmap", "fused"):
        _search_pair(built["reference"], built["auto"], queries, engine)


def test_snapshot_roundtrip_fused_build_and_old_widths(rng, tmp_path):
    """Fused-built snapshot round-trips bit-identically, and a snapshot
    whose arrays were written with the pre-narrowing dtypes (f32/int32)
    still loads into the narrow layout with identical answers."""
    data = jnp.asarray(make_clustered(rng, 512, 10))
    queries = jnp.asarray(make_clustered(rng, 8, 10))
    p = derive_params(K=4, c=1.5, L=2, beta_override=0.1)
    idx = DETLSH.build(data, jax.random.key(3), p, leaf_size=16)
    path = tmp_path / "snap"
    idx.save(path)
    loaded = repro.api.load(path)
    _assert_forests_equal(idx.forest, loaded.forest)
    _search_pair(idx, loaded, queries, "fused")

    # Simulate an old-format snapshot: widen the stored forest arrays the
    # way the pre-narrowing code wrote them (codes/bounds int32), and mark
    # the manifest pre-digest (format_version 2) as that era's saver did.
    arrs = dict(np.load(path / "arrays.npz"))
    for k in ("forest.codes_sorted", "forest.leaf_lo", "forest.leaf_hi"):
        arrs[k] = arrs[k].astype(np.int32)
    np.savez(path / "arrays.npz", **arrs)
    manifest = json.load(open(path / "MANIFEST.json"))
    del manifest["digests"]
    manifest["format_version"] = 2
    with open(path / "MANIFEST.json", "w") as f:
        json.dump(manifest, f)
    with pytest.warns(UserWarning, match="pre-digest"):
        wide = repro.api.load(path)
    assert wide.forest.codes_sorted.dtype == CODE_DTYPE
    assert wide.forest.leaf_lo.dtype == LEAF_DTYPE
    _assert_forests_equal(idx.forest, wide.forest, msg="old-width ")
    _search_pair(idx, wide, queries, "fused")


def test_pdet_snapshot_search_identical_to_fused_build(rng, tmp_path):
    """A placed (1-shard) PDET build + snapshot reload answers bit-
    identically to the old-path single-device build — the device-count-
    invariance contract is untouched by the fused builder (the multi-shard
    variants run in the multidevice CI job)."""
    data = jnp.asarray(make_clustered(rng, 512, 10))
    queries = jnp.asarray(make_clustered(rng, 8, 10))
    spec = repro.api.IndexSpec(K=4, L=2, c=1.5, beta_override=0.1,
                               leaf_size=16,
                               placement=repro.api.PlacementSpec((1,)))
    pdet = repro.api.build(data, jax.random.key(4), spec)
    old = DETLSH.from_spec(
        data, jax.random.key(4),
        dataclasses.replace(spec, placement=None, build_impl="reference"))
    path = tmp_path / "pdet"
    pdet.save(path)
    loaded = repro.api.load(path)
    req = repro.api.SearchRequest(k=8, r_min=0.5)
    r_old = old.search(queries, dataclasses.replace(req, engine="fused"))
    for idx in (pdet, loaded):
        r = idx.search(queries, req)
        assert r.stats.engine == "pdet"
        np.testing.assert_array_equal(np.asarray(r.ids),
                                      np.asarray(r_old.ids))
        np.testing.assert_array_equal(np.asarray(r.dists),
                                      np.asarray(r_old.dists))


# ---------------------------------------------------------------------------
# Sharded per-shard builds (the multidevice CI job runs these for real)
# ---------------------------------------------------------------------------

def test_serial_reference_shards_match_fused_local_build(rng):
    """Per-shard forests: the fused shared pipeline == the reference
    per-tree builder that ``serial_reference_build`` still uses, shard by
    shard (same breakpoints, same arrays)."""
    from repro.core import encoding as enc, hashing
    from repro.core.detree import fused_forest_arrays
    from repro.core.distributed import serial_reference_build
    data = make_clustered(rng, 1024, 12)
    p = derive_params(K=4, c=1.5, L=2, beta_override=0.1)
    n_shards = 4
    A, parts, edges = serial_reference_build(
        jnp.asarray(data), jax.random.key(5), p, n_shards, leaf_size=16)
    shards = jnp.asarray(data).reshape(n_shards, -1, data.shape[1])
    for s in range(n_shards):
        proj = hashing.project(shards[s], A)
        got = fused_forest_arrays(proj, edges, K=p.K, L=p.L, leaf_size=16)
        for k, v in got.items():
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(parts[k][s]),
                err_msg=f"shard {s} {k}")


@pytest.mark.multidevice
@pytest.mark.skipif(len(jax.devices()) < 4, reason="needs 4 devices")
def test_multidevice_fused_build_matches_reference_build(rng):
    """On a real 4-device mesh: a fused-built placed index answers bit-
    identically to the reference-built one (sharded build produces the
    same per-shard forests)."""
    data = jnp.asarray(make_clustered(rng, 1024, 12))
    queries = jnp.asarray(make_clustered(rng, 8, 12))
    spec = repro.api.IndexSpec(K=4, L=2, c=1.5, beta_override=0.1,
                               leaf_size=16,
                               placement=repro.api.PlacementSpec((4,)))
    fused = repro.api.build(data, jax.random.key(6), spec)
    ref = repro.api.build(data, jax.random.key(6),
                          dataclasses.replace(spec, build_impl="reference"))
    _assert_forests_equal(
        type(fused.forest)(n=fused.forest.n,
                           leaf_size=fused.forest.leaf_size,
                           **{k: jax.device_get(getattr(fused.forest, k))
                              for k in _FOREST_KEYS}),
        type(ref.forest)(n=ref.forest.n, leaf_size=ref.forest.leaf_size,
                         **{k: jax.device_get(getattr(ref.forest, k))
                            for k in _FOREST_KEYS}),
        msg="sharded ")
    req = repro.api.SearchRequest(k=8, r_min=0.5)
    ra, rb = fused.search(queries, req), ref.search(queries, req)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists),
                                  np.asarray(rb.dists))
