"""Tests for the DE-Forest: build invariants + LB/UB admissibility."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hashing
from repro.core.detree import build_forest, leaf_bounds


def _build(n=2048, d=16, K=4, L=2, leaf_size=32, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((n, d)).astype(np.float32)
    A = hashing.sample_projections(jax.random.key(seed), d, K, L)
    proj = np.asarray(data @ np.asarray(A))
    forest = build_forest(jnp.asarray(proj), K, L, Nr=64, leaf_size=leaf_size,
                          breakpoint_method="full_sort")
    return data, proj, forest


def test_forest_shapes_and_permutation():
    n, K, L, ls = 1000, 4, 3, 32
    data, proj, forest = _build(n=n, K=K, L=L, leaf_size=ls)
    n_leaves = -(-n // ls)
    assert forest.point_ids.shape == (L, n_leaves * ls)
    assert forest.leaf_lo.shape == (L, n_leaves, K)
    for l in range(L):
        ids = np.asarray(forest.point_ids[l])
        valid = np.asarray(forest.valid[l])
        assert valid.sum() == n
        real = np.sort(ids[valid], kind="stable")
        np.testing.assert_array_equal(real, np.arange(n))
        assert np.all(ids[~valid] == n)


def test_sorted_projections_match_ids():
    data, proj, forest = _build(n=500, K=4, L=2, leaf_size=16)
    L, K = forest.L, forest.K
    p = proj.reshape(-1, L, K)
    for l in range(L):
        ids = np.asarray(forest.point_ids[l])
        valid = np.asarray(forest.valid[l])
        got = np.asarray(forest.proj_sorted[l])[valid]
        want = p[ids[valid], l, :]
        np.testing.assert_allclose(got, want, rtol=1e-6)


def test_leaf_intervals_cover_members():
    """Every point's region code lies inside its leaf's [lo, hi] interval."""
    data, proj, forest = _build(n=1500, K=4, L=2, leaf_size=64)
    for l in range(forest.L):
        codes = np.asarray(forest.codes_sorted[l])
        valid = np.asarray(forest.valid[l])
        lo = np.asarray(forest.leaf_lo[l])
        hi = np.asarray(forest.leaf_hi[l])
        ls = forest.leaf_size
        for leaf in range(forest.n_leaves):
            sl = slice(leaf * ls, (leaf + 1) * ls)
            cm = codes[sl][valid[sl]]
            if cm.size == 0:
                continue
            assert np.all(cm >= lo[leaf][None, :])
            assert np.all(cm <= hi[leaf][None, :])


def test_morton_sort_groups_prefixes():
    """Code-sorted order: identical codes must be contiguous."""
    data, proj, forest = _build(n=4096, K=2, L=1, leaf_size=16)
    codes = np.asarray(forest.codes_sorted[0])[np.asarray(forest.valid[0])]
    # interleave to a scalar key (K=2, 8 bits each fits 16 bits-per-level scheme)
    seen = set()
    prev = None
    for c in map(tuple, codes):
        if c != prev and c in seen:
            pytest.fail(f"code {c} appears in two separate runs")
        seen.add(c)
        prev = c


def _bounds_vs_truth(forest, q_proj, l):
    lb, ub = leaf_bounds(jnp.asarray(q_proj), forest.leaf_lo[l],
                         forest.leaf_hi[l], forest.leaf_valid[l],
                         forest.breakpoints[l])
    lb, ub = np.asarray(lb), np.asarray(ub)
    proj_s = np.asarray(forest.proj_sorted[l])
    valid = np.asarray(forest.valid[l])
    d = np.sqrt(((proj_s - q_proj[None, :]) ** 2).sum(-1))
    ls = forest.leaf_size
    for leaf in range(forest.n_leaves):
        sl = slice(leaf * ls, (leaf + 1) * ls)
        dm = d[sl][valid[sl]]
        if dm.size == 0:
            assert np.isinf(lb[leaf])
            continue
        tol = 1e-4 * max(1.0, dm.max())
        assert lb[leaf] <= dm.min() + tol, (leaf, lb[leaf], dm.min())
        assert ub[leaf] >= dm.max() - tol, (leaf, ub[leaf], dm.max())


def test_leaf_bounds_admissible():
    """Paper Fig. 5: LB <= dist(q, o) <= UB for every o in the leaf."""
    data, proj, forest = _build(n=2000, K=4, L=2, leaf_size=32, seed=3)
    rng = np.random.default_rng(7)
    for l in range(forest.L):
        for _ in range(4):
            q_proj = rng.standard_normal(forest.K).astype(np.float32) * 3
            _bounds_vs_truth(forest, q_proj, l)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 3), st.integers(0, 10 ** 6))
def test_property_leaf_bounds_admissible(K, L, seed):
    """Property: bound admissibility holds across K, L, and data seeds."""
    rng = np.random.default_rng(seed)
    n = 256
    proj = (rng.standard_normal((n, L * K)) * rng.uniform(0.5, 4)).astype(
        np.float32)
    forest = build_forest(jnp.asarray(proj), K, L, Nr=16, leaf_size=16,
                          breakpoint_method="full_sort")
    q_proj = rng.standard_normal(K).astype(np.float32) * 2
    _bounds_vs_truth(forest, q_proj, rng.integers(0, L))


def test_index_size_scales_linearly():
    _, _, f1 = _build(n=1024, K=4, L=2)
    _, _, f2 = _build(n=4096, K=4, L=2)
    assert 3.0 < f2.size_bytes() / f1.size_bytes() < 5.0
