"""Training substrate tests: optimizer, quantization, checkpointing,
data pipeline, gradient compression, end-to-end loss descent + resume."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import PipelineConfig, SyntheticLM, make_pipeline
from repro.models import transformer as T
from repro.train import checkpoint as ckpt
from repro.train import quant
from repro.train.compression import compress_psum
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   schedule)
from repro.train.train_step import make_train_step

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (4, 130), (3, 5, 128), ()])
def test_quant_roundtrip(rng, shape):
    x = jnp.asarray(rng.standard_normal(shape).astype(np.float32) * 3)
    q, s = quant.quantize(x)
    assert q.shape == x.shape
    y = quant.dequantize(q, s)
    err = np.abs(np.asarray(y) - np.asarray(x))
    tol = np.abs(np.asarray(x)).max() / 100 if x.size else 0
    assert err.max() <= tol + 1e-6


def test_quant_relative_error_blockwise(rng):
    # mixed magnitudes across blocks: blockwise scales keep both accurate
    # (error bound per block: half a quantization step = absmax/254)
    a = rng.standard_normal(128) * 1000
    b = rng.standard_normal(128) * 0.001
    x = jnp.asarray(np.concatenate([a, b]).astype(np.float32))
    q, s = quant.quantize(x)
    y = np.asarray(quant.dequantize(q, s))
    assert np.abs(y[:128] - a).max() <= np.abs(a).max() / 254 + 1e-6
    assert np.abs(y[128:] - b).max() <= np.abs(b).max() / 254 + 1e-9


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _quad_problem():
    target = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32).reshape(4, 8)
    params = {"w": jnp.zeros((4, 8))}

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("state_dtype", ["float32", "int8"])
def test_adamw_converges(state_dtype):
    params, loss, target = _quad_problem()
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                      total_steps=400, state_dtype=state_dtype)
    state = adamw_init(params, cfg)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2] == pytest.approx(1.0, rel=1e-3)
    assert lrs[3] < lrs[2] and lrs[4] == pytest.approx(cfg.min_lr_frac,
                                                       rel=1e-2)


def test_grad_clipping_bounds_update():
    params, loss, _ = _quad_problem()
    cfg = AdamWConfig(lr=0.1, max_grad_norm=1e-3, warmup_steps=1)
    state = adamw_init(params, cfg)
    g = jax.tree.map(lambda p: jnp.full_like(p, 1e6), params)
    _, _, metrics = adamw_update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


# ---------------------------------------------------------------------------
# Gradient compression (error feedback)
# ---------------------------------------------------------------------------

def test_compression_error_feedback_reduces_bias(rng):
    g = jnp.asarray(rng.standard_normal((4, 256)).astype(np.float32))
    res = jnp.zeros_like(g)
    # without collective axes: psum == identity; accumulate over steps
    acc_comp = jnp.zeros_like(g)
    acc_true = jnp.zeros_like(g)
    for _ in range(50):
        out, res = compress_psum(g, res, ())
        acc_comp = acc_comp + out
        acc_true = acc_true + g
    rel = float(jnp.linalg.norm(acc_comp - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.01  # error feedback keeps long-run bias tiny


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    pc = PipelineConfig(seed=7, vocab_size=128, seq_len=16, global_batch=4)
    p1 = SyntheticLM(pc)
    p2 = SyntheticLM(pc)
    b1 = p1.batch_at(12)
    b2 = p2.batch_at(12)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # labels are next-token targets
    np.testing.assert_array_equal(np.asarray(b1["tokens"])[:, 1:],
                                  np.asarray(b1["labels"])[:, :-1])


def test_pipeline_host_sharding_partitions_batch():
    full = SyntheticLM(PipelineConfig(seed=3, global_batch=8, seq_len=8,
                                      host_index=0, host_count=1))
    h0 = SyntheticLM(PipelineConfig(seed=3, global_batch=8, seq_len=8,
                                    host_index=0, host_count=2))
    h1 = SyntheticLM(PipelineConfig(seed=3, global_batch=8, seq_len=8,
                                    host_index=1, host_count=2))
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    # different hosts generate different (disjoint-stream) data
    assert not np.array_equal(np.asarray(h0.batch_at(0)["tokens"]),
                              np.asarray(h1.batch_at(0)["tokens"]))


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    ckpt.save(str(tmp_path), 5, tree, extra={"next_step": 5})
    out, extra = ckpt.restore(str(tmp_path), None, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert extra["next_step"] == 5
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_skips_partial_and_detects_corruption(tmp_path):
    tree = {"a": jnp.ones((4,))}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # simulate a crash mid-write of step 3: dir without manifest
    os.makedirs(tmp_path / "step_00000003")
    assert ckpt.latest_step(str(tmp_path)) == 2
    # corrupt step 2's array -> restore must raise
    bad = np.zeros((4,), np.float32)
    np.save(tmp_path / "step_00000002" / "arr_0.npy", bad + 99)
    with pytest.raises(ValueError, match="checksum|corrupt"):
        ckpt.restore(str(tmp_path), 2, tree)
    # step 1 still restorable
    out, _ = ckpt.restore(str(tmp_path), 1, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones((4,)))


def test_checkpoint_gc(tmp_path):
    tree = {"a": jnp.ones(2)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.garbage_collect(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2


# ---------------------------------------------------------------------------
# End-to-end: loss descends; crash + resume continues identically
# ---------------------------------------------------------------------------

def test_train_loss_descends():
    cfg = get_config("qwen3-1.7b").reduced()
    shape = ShapeConfig("t", 32, 4, "train")
    pipe = make_pipeline(cfg, shape, seed=0)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    params = T.init_params(cfg, jax.random.key(0))
    state = adamw_init(params, opt_cfg)
    losses = []
    for s in range(25):
        params, state, m = step_fn(params, state, pipe.batch_at(s))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_train_driver_resume_identical(tmp_path):
    """Run 6 steps; separately run 3, 'crash', resume 3 — same params."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu")
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "qwen3-1.7b", "--reduced", "--batch", "4", "--seq", "32",
              "--ckpt-every", "3", "--keep", "5"]

    d1 = tmp_path / "a"
    r = subprocess.run(common + ["--steps", "6", "--ckpt-dir", str(d1)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]

    d2 = tmp_path / "b"
    r = subprocess.run(common + ["--steps", "3", "--ckpt-dir", str(d2)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    r = subprocess.run(common + ["--steps", "6", "--ckpt-dir", str(d2)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed" in r.stdout

    cfg = get_config("qwen3-1.7b").reduced()
    like_p = T.init_params(cfg, jax.random.key(0))
    opt_cfg = AdamWConfig(state_dtype="float32")
    like = (like_p, adamw_init(like_p, opt_cfg))
    t1, _ = ckpt.restore(str(d1), 6, like)
    t2, _ = ckpt.restore(str(d2), 6, like)
    for a, b in zip(jax.tree.leaves(t1[0]), jax.tree.leaves(t2[0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-5,
                                   atol=2e-5)
