"""Tests for the Lemma 3 / Theorem 1-3 parameter machinery."""

import math

import numpy as np
import pytest
from scipy.stats import chi2

from repro.core import theory


def test_chi2_upper_quantile_roundtrip():
    for k in (1, 4, 16, 64):
        for a in (0.05, 0.3, 0.7788):
            y = theory.chi2_upper_quantile(a, k)
            assert math.isclose(chi2.sf(y, k), a, rel_tol=1e-9)


def test_chi2_cdf_jax_matches_scipy():
    ys = np.linspace(0.1, 60.0, 23)
    for k in (4, 16):
        got = np.asarray(theory.chi2_cdf_jax(ys, k))
        want = chi2.cdf(ys, k)
        np.testing.assert_allclose(got, want, atol=2e-6)


def test_lemma3_coupling():
    """eps^2 = chi2_{a1}(K) = c^2 chi2_{a2}(K) must hold simultaneously."""
    for K, c, L in [(16, 1.5, 4), (4, 1.5, 16), (16, 2.0, 2), (8, 1.2, 8)]:
        p = theory.derive_params(K=K, c=c, L=L)
        assert math.isclose(p.alpha1, math.exp(-1.0 / L), rel_tol=1e-12)
        assert math.isclose(p.epsilon ** 2,
                            theory.chi2_upper_quantile(p.alpha1, K),
                            rel_tol=1e-9)
        assert math.isclose(p.epsilon ** 2 / c ** 2,
                            theory.chi2_upper_quantile(p.alpha2, K),
                            rel_tol=1e-6)
        assert math.isclose(p.beta, 2 - 2 * p.alpha2 ** L, rel_tol=1e-9)


def test_event_probability_bounds():
    """Lemma 3: Pr[E1] >= 1 - 1/e and Pr[E3] >= 1/2 (with theoretical beta)."""
    for K, c, L in [(16, 1.5, 4), (4, 1.5, 16)]:
        p = theory.derive_params(K=K, c=c, L=L)
        ev = theory.event_probabilities(p)
        assert ev["pr_E1"] >= 1 - 1 / math.e - 1e-9
        assert ev["pr_E3"] >= 0.5 - 1e-9
        assert p.success_probability == pytest.approx(0.5 - 1 / math.e)


def test_beta_monotone_decreasing_in_L():
    """Paper Fig. 6: beta drops with L (rapidly until L=4)."""
    betas = theory.beta_of_L(16, 1.5, np.arange(1, 13))
    assert np.all(np.diff(betas) < 0)
    # "beta drops rapidly until L=4, then slowly"
    drop_early = betas[0] - betas[3]
    drop_late = betas[3] - betas[7]
    assert drop_early > drop_late


def test_derive_params_validates():
    with pytest.raises(ValueError):
        theory.derive_params(K=0)
    with pytest.raises(ValueError):
        theory.derive_params(c=1.0)
    with pytest.raises(ValueError):
        theory.chi2_upper_quantile(0.0, 4)
