"""Behavior of the unified repro.api surface: eager config validation,
engine-registry resolution rules, protocol conformance of both index
kinds, the per-(index, k) r_min cache, and the deprecation shims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.api import (AnnIndex, IndexSpec, LegacyIndexAdapter,
                       MutableAnnIndex, SearchRequest, as_ann_index,
                       available_engines, resolve_engine)
from repro.core import DETLSH
from repro.core.query import QueryConfig
from tests.conftest import make_clustered, make_queries_near

D = 16


@pytest.fixture(scope="module")
def static_idx():
    rng = np.random.default_rng(0)
    data = make_clustered(rng, 1024, D)
    spec = IndexSpec(kind="static", K=4, L=4, c=1.5, beta_override=0.1,
                     Nr=32, leaf_size=16)
    idx = repro.api.build(jnp.asarray(data), jax.random.key(0), spec)
    return idx, data, rng


# ---------------------------------------------------------------------------
# Eager validation (satellite: actionable errors, not deep-loop misbehavior)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs", [
    dict(k=0), dict(k=-3), dict(M=0), dict(max_rounds=0), dict(r_min=0.0),
    dict(r_min=-1.0), dict(mode="lief"), dict(engine="fussed"),
    dict(dist_impl="cuda"), dict(bounds_impl="nope"), dict(n_active=-1),
])
def test_search_request_rejects_bad_fields(kwargs):
    with pytest.raises(ValueError):
        SearchRequest(**kwargs)


@pytest.mark.parametrize("kwargs", [
    dict(kind="sharded"), dict(K=0), dict(L=0), dict(c=1.0), dict(c=0.5),
    dict(Nr=1), dict(leaf_size=0), dict(breakpoint_method="quantile"),
    dict(engine="fussed"), dict(delta_capacity=0), dict(max_segments=0),
    dict(id_capacity=0), dict(project_impl="cuda"), dict(beta_override=-0.1),
])
def test_index_spec_rejects_bad_fields(kwargs):
    with pytest.raises(ValueError):
        IndexSpec(**kwargs)


@pytest.mark.parametrize("kwargs", [
    dict(k=0), dict(M=0), dict(max_rounds=0), dict(r_min=0.0),
    dict(mode="lief"), dict(engine="fussed"), dict(dist_impl="cuda"),
    dict(block_q=0),
])
def test_query_config_rejects_bad_fields(kwargs):
    with pytest.raises(ValueError):
        QueryConfig(**kwargs)


def test_error_messages_name_the_valid_choices():
    with pytest.raises(ValueError, match="vmap"):
        SearchRequest(engine="typo")
    with pytest.raises(ValueError, match="strict"):
        SearchRequest(mode="typo")
    with pytest.raises(ValueError, match="streaming"):
        IndexSpec(kind="typo")


# ---------------------------------------------------------------------------
# Engine registry resolution (replaces _pick_engine string matching)
# ---------------------------------------------------------------------------

def test_resolution_rules():
    assert resolve_engine("auto", mode="leaf", batch=64) == "fused"
    assert resolve_engine("auto", mode="leaf", batch=2) == "vmap"
    assert resolve_engine("auto", mode="leaf", batch=None) == "fused"
    # explicit fused ignores min_batch
    assert resolve_engine("fused", mode="leaf", batch=1) == "fused"
    assert resolve_engine("vmap", mode="leaf", batch=64) == "vmap"
    # strict-mode fallback is explicit: fused does not support strict
    assert resolve_engine("auto", mode="strict", batch=64) == "vmap"
    assert resolve_engine("fused", mode="strict", batch=64) == "vmap"
    assert resolve_engine(None, mode="leaf", batch=64) == "fused"
    with pytest.raises(ValueError, match="auto"):
        resolve_engine("typo", mode="leaf", batch=64)


def test_registry_round_trip_custom_engine():
    from repro.api import get_engine, register_engine
    from repro.api import registry as reg
    calls = []

    def run(*a, **kw):
        calls.append(1)
        return get_engine("vmap").run(*a, **kw)

    register_engine("test-echo", run, modes=("leaf",), min_batch=1,
                    priority=99)
    try:
        assert available_engines()[0] == "test-echo"
        assert resolve_engine("auto", mode="leaf", batch=64) == "test-echo"
        SearchRequest(engine="test-echo")    # validation accepts it
    finally:
        del reg._ENGINES["test-echo"]
    assert resolve_engine("auto", mode="leaf", batch=64) == "fused"


# ---------------------------------------------------------------------------
# Protocol conformance (acceptance criterion)
# ---------------------------------------------------------------------------

def test_both_indexes_satisfy_the_protocol(static_idx):
    idx, data, rng = static_idx
    assert isinstance(idx, AnnIndex)
    assert not isinstance(idx, MutableAnnIndex)
    assert as_ann_index(idx) is idx

    sidx = repro.api.build(
        jnp.asarray(data), jax.random.key(1),
        IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=32, leaf_size=16, delta_capacity=32))
    assert isinstance(sidx, AnnIndex)
    assert isinstance(sidx, MutableAnnIndex)
    assert as_ann_index(sidx) is sidx


def test_legacy_adapter_wraps_query_only_objects(static_idx):
    idx, data, rng = static_idx

    class Legacy:
        def __init__(self, inner):
            self._inner = inner

        def query(self, queries, k=10):
            # A pre-protocol surface; implemented on the typed search so
            # the suite stays clean under -W error::DeprecationWarning.
            return self._inner.search(queries, SearchRequest(k=k)).raw

    adapted = as_ann_index(Legacy(idx))
    assert isinstance(adapted, LegacyIndexAdapter)
    assert not adapted.supports_n_active
    q = jnp.asarray(make_queries_near(data, rng, 4))
    res = adapted.search(q, SearchRequest(k=5, n_active=2))  # dropped, ok
    assert res.ids.shape == (4, 5)
    with pytest.raises(TypeError, match="no query"):
        as_ann_index(object())


def test_build_rejects_mismatched_kind(static_idx):
    idx, data, rng = static_idx
    from repro.streaming import StreamingDETLSH
    with pytest.raises(ValueError, match="static"):
        DETLSH.from_spec(jnp.asarray(data), jax.random.key(0),
                         IndexSpec(kind="streaming"))
    with pytest.raises(ValueError, match="streaming"):
        StreamingDETLSH.from_spec(jnp.asarray(data), jax.random.key(0),
                                  IndexSpec(kind="static"))


# ---------------------------------------------------------------------------
# r_min caching (satellite: one estimate per (index, k), not per batch)
# ---------------------------------------------------------------------------

def test_r_min_estimated_once_per_k(static_idx, monkeypatch):
    import repro.core as core
    idx, data, rng = static_idx
    idx._r_min_cache.clear()
    counter = {"n": 0}
    real = core.estimate_r_min

    def counting(*a, **kw):
        counter["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(core, "estimate_r_min", counting)
    q = jnp.asarray(make_queries_near(data, rng, 4))
    r1 = idx.search(q, SearchRequest(k=5))
    r2 = idx.search(q, SearchRequest(k=5))
    assert counter["n"] == 1               # second batch reuses the cache
    assert r1.stats.r_min == r2.stats.r_min == idx.r_min_for(5)
    assert not r1.stats.r_min_cached       # first search pays the estimate
    assert r2.stats.r_min_cached           # ...and the second is a hit
    idx.search(q, SearchRequest(k=9))
    assert counter["n"] == 2               # distinct k => distinct estimate
    res = idx.search(q, SearchRequest(k=5, r_min=2.5))
    assert res.stats.r_min == 2.5 and not res.stats.r_min_cached
    assert counter["n"] == 2               # explicit r_min bypasses


def test_streaming_r_min_cache_invalidated_by_mutation(static_idx):
    idx, data, rng = static_idx
    sidx = repro.api.build(
        jnp.asarray(data[:256]), jax.random.key(1),
        IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=32, leaf_size=16, delta_capacity=32))
    q = jnp.asarray(make_queries_near(data, rng, 4))
    sidx.search(q, SearchRequest(k=5))
    tag0, cache0 = sidx._rmin_cache
    assert 5 in cache0
    sidx.upsert(make_clustered(rng, 3, D))
    sidx.search(q, SearchRequest(k=5))
    tag1, cache1 = sidx._rmin_cache
    assert tag1 != tag0                    # mutation invalidated the cache


# ---------------------------------------------------------------------------
# Deprecation shims + engine default from the spec
# ---------------------------------------------------------------------------

def test_query_shim_warns_and_matches_search(static_idx):
    idx, data, rng = static_idx
    q = jnp.asarray(make_queries_near(data, rng, 8))
    with pytest.warns(DeprecationWarning, match="search"):
        old = idx.query(q, k=5)
    new = idx.search(q, SearchRequest(k=5))
    np.testing.assert_array_equal(np.asarray(old.ids), np.asarray(new.ids))
    np.testing.assert_array_equal(np.asarray(old.dists),
                                  np.asarray(new.dists))


def test_streaming_query_shim_warns(static_idx):
    idx, data, rng = static_idx
    sidx = repro.api.build(
        jnp.asarray(data[:256]), jax.random.key(1),
        IndexSpec(kind="streaming", K=4, L=4, c=1.5, beta_override=0.1,
                  Nr=32, leaf_size=16, delta_capacity=32))
    q = jnp.asarray(make_queries_near(data, rng, 4))
    with pytest.warns(DeprecationWarning, match="search"):
        old = sidx.query(q, k=5)
    new = sidx.search(q, SearchRequest(k=5))
    np.testing.assert_array_equal(np.asarray(old.ids), np.asarray(new.ids))


def test_spec_engine_is_the_search_default(static_idx):
    idx, data, rng = static_idx
    q = jnp.asarray(make_queries_near(data, rng, 16))
    import dataclasses
    vmap_idx = dataclasses.replace(idx)
    vmap_idx.spec = dataclasses.replace(idx.spec, engine="vmap")
    res = vmap_idx.search(q, SearchRequest(k=5))
    assert res.stats.engine == "vmap"      # spec default, batch >= 8
    res = vmap_idx.search(q, SearchRequest(k=5, engine="fused"))
    assert res.stats.engine == "fused"     # request overrides spec
    res = idx.search(q, SearchRequest(k=5))
    assert res.stats.engine == "fused"     # plain auto at batch 16
