"""Tests for the query phase: Alg. 3/4/5 semantics + quality guarantees."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SearchRequest
from repro.core import DETLSH, derive_params, estimate_r_min
from repro.core.query import QueryConfig, knn_query, rc_ann_query
from tests.conftest import brute_force_knn, make_clustered


@pytest.fixture(scope="module")
def built(small_dataset):
    data, queries = small_dataset
    p = derive_params(K=4, c=1.5, L=16, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(0), p, leaf_size=64)
    return idx, data, queries


def test_knn_returns_valid_sorted(built):
    idx, data, queries = built
    k = 10
    res = idx.search(jnp.asarray(queries), SearchRequest(k=k))
    ids = np.asarray(res.ids)
    dd = np.asarray(res.dists)
    n = data.shape[0]
    assert ids.shape == (len(queries), k)
    assert np.all((ids >= 0) & (ids < n))          # all valid
    assert np.all(np.diff(dd, axis=1) >= -1e-5)    # ascending distances
    # reported distances must equal true distances of returned ids
    true = np.sqrt(((data[ids] - queries[:, None, :]) ** 2).sum(-1))
    np.testing.assert_allclose(dd, true, rtol=1e-4, atol=1e-4)


def test_c2_ratio_guarantee(built):
    """Theorem 2: each returned o_i has ||q,o_i|| <= c^2 ||q,o_i*|| for at
    least a (1/2 - 1/e) fraction — empirically it holds for nearly all."""
    idx, data, queries = built
    k = 10
    res = idx.search(jnp.asarray(queries), SearchRequest(k=k))
    dd = np.asarray(res.dists)
    _, gt_d = brute_force_knn(data, queries, k)
    c2 = idx.params.c ** 2
    ok = np.all(dd <= c2 * gt_d + 1e-4, axis=1)
    assert ok.mean() >= idx.params.success_probability, ok.mean()


def test_recall_reasonable_on_clustered(built):
    idx, data, queries = built
    k = 10
    res = idx.search(jnp.asarray(queries), SearchRequest(k=k, M=16))
    gt_i, _ = brute_force_knn(data, queries, k)
    ids = np.asarray(res.ids)
    recall = np.mean([len(set(ids[i]) & set(gt_i[i])) / k
                      for i in range(len(queries))])
    assert recall >= 0.5, recall


def test_termination_conditions(built):
    """T1: |S| stops at >= beta*n + k (within one round's cap)."""
    idx, data, queries = built
    n = data.shape[0]
    k = 10
    res = idx.search(jnp.asarray(queries), SearchRequest(k=k))
    count = np.asarray(res.stats.n_candidates)
    rounds = np.asarray(res.stats.rounds)
    cap_round = idx.params.L * 8 * idx.forest.leaf_size
    assert np.all(rounds >= 1)
    t1_bound = idx.params.beta * n + k + cap_round
    assert np.all(count <= t1_bound)


def test_strict_mode_subset_of_leaf_mode(built):
    """Unoptimized Alg. 3 (strict) examines a subset of the optimized
    leaf-granularity candidates -> its |S| can only be smaller."""
    idx, data, queries = built
    q = jnp.asarray(queries[0])
    r0 = estimate_r_min(idx.data, jnp.asarray(queries), 10, idx.params.c)
    for mode, counts in (("strict", []), ("leaf", [])):
        pass
    cfg_leaf = QueryConfig(k=10, M=8, r_min=r0, mode="leaf")
    cfg_strict = QueryConfig(k=10, M=8, r_min=r0, mode="strict")
    res_l = knn_query(idx.data, idx.forest, idx.A, idx.params, q, cfg_leaf)
    res_s = knn_query(idx.data, idx.forest, idx.A, idx.params, q, cfg_strict)
    assert int(res_s.n_candidates) <= int(res_l.n_candidates) + 1


def test_rc_ann_query_contract(built):
    """Definition 3: if it returns a point o', then ||q,o'|| <= c*r when a
    point within r exists."""
    idx, data, queries = built
    n = data.shape[0]
    c = idx.params.c
    gt_i, gt_d = brute_force_knn(data, queries[:4], 1)
    cfg = QueryConfig(k=1, M=16)
    hits = 0
    for qi in range(4):
        r = float(gt_d[qi, 0]) * 1.05     # a point within r exists
        res = rc_ann_query(idx.data, idx.forest, idx.A, idx.params,
                           jnp.asarray(queries[qi]), r, cfg)
        oid = int(res.ids[0])
        if oid < n:
            assert float(res.dists[0]) <= c * r + 1e-4
            hits += 1
    # constant success probability: with 4 easy queries expect >= 1 hit
    assert hits >= 1


def test_increasing_M_does_not_reduce_candidates(built):
    idx, data, queries = built
    q = jnp.asarray(queries[1])
    r0 = estimate_r_min(idx.data, jnp.asarray(queries), 10, idx.params.c)
    counts = []
    for M in (2, 8, 24):
        cfg = QueryConfig(k=10, M=M, r_min=r0)
        res = knn_query(idx.data, idx.forest, idx.A, idx.params, q, cfg)
        counts.append(int(res.n_candidates))
    assert counts[0] <= counts[1] + 1 and counts[1] <= counts[2] + 1


def test_full_budget_quality_on_tiny_dataset():
    """With a candidate budget >= n, every returned point must satisfy the
    per-point c^2 bound (T2 may still stop the scan early — the contract is
    the ratio, not exactness) and recall should be near-perfect."""
    rng = np.random.default_rng(11)
    data = make_clustered(rng, 512, 8)
    queries = make_clustered(rng, 4, 8)
    p = derive_params(K=4, c=1.5, L=4, beta_override=1.0)  # beta*n = n
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(1), p, leaf_size=16)
    res = idx.search(jnp.asarray(queries),
                     SearchRequest(k=5, M=32, max_rounds=64))
    gt_i, gt_d = brute_force_knn(data, queries, 5)
    dd = np.asarray(res.dists)
    assert np.all(dd <= p.c ** 2 * gt_d + 1e-4)
    ids = np.asarray(res.ids)
    recall = np.mean([len(set(ids[i]) & set(gt_i[i])) / 5 for i in range(4)])
    assert recall >= 0.8, recall


from hypothesis import given, settings, strategies as st


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from([(4, 8), (8, 4)]),
       st.floats(1.2, 2.0))
def test_property_c2_guarantee_across_datasets(seed, KL, c):
    """Property: the per-point c^2 bound holds at >= the Theorem-2 rate
    across data seeds, (K, L) settings, and approximation ratios."""
    K, L = KL
    rng = np.random.default_rng(seed)
    data = make_clustered(rng, 2048, 12)
    queries = make_clustered(rng, 6, 12)
    p = derive_params(K=K, c=float(c), L=L, beta_override=0.1)
    idx = DETLSH.build(jnp.asarray(data), jax.random.key(seed % 997), p,
                       leaf_size=32)
    res = idx.search(jnp.asarray(queries), SearchRequest(k=5, M=8))
    _, gt_d = brute_force_knn(data, queries, 5)
    ok = np.all(np.asarray(res.dists) <= p.c ** 2 * gt_d + 1e-4, axis=1)
    assert ok.mean() >= p.success_probability, (ok.mean(), seed, K, L, c)
