"""GPipe pipeline parallelism: pipelined == sequential, grads flow."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    import jax, jax.numpy as jnp, numpy as np
    sys.path.insert(0, {src!r})
    from repro.launch.mesh import make_mesh
    from repro.train.pipeline import pipeline_apply, sequential_reference

    rng = np.random.default_rng(0)
    S, M, mb, d = 4, 6, 2, 8
    mesh = make_mesh((S,), ("stage",))
    params = {{"w": jnp.asarray(rng.standard_normal((S, d, d)).astype(
        np.float32) * 0.3)}}
    x = jnp.asarray(rng.standard_normal((M, mb, d)).astype(np.float32))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])

    got = pipeline_apply(params, x, stage_fn, mesh, axis="stage")
    want = sequential_reference(params, x, stage_fn)
    fwd_err = float(jnp.abs(got - want).max())

    def loss_pipe(p):
        return (pipeline_apply(p, x, stage_fn, mesh, axis="stage") ** 2).sum()

    def loss_seq(p):
        return (sequential_reference(p, x, stage_fn) ** 2).sum()

    g1 = jax.grad(loss_pipe)(params)["w"]
    g2 = jax.grad(loss_seq)(params)["w"]
    grad_err = float(jnp.abs(g1 - g2).max() / (jnp.abs(g2).max() + 1e-9))
    print(json.dumps(dict(fwd_err=fwd_err, grad_err=grad_err)))
""")


@pytest.mark.slow
def test_gpipe_matches_sequential_and_grads():
    script = _SCRIPT.format(src=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["fwd_err"] < 1e-5, payload
    assert payload["grad_err"] < 1e-4, payload
