"""Model-layer correctness: flash attention, SSD, MoE, decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.kernels import ref
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T


def _t(rng, *s, scale=0.5):
    return jnp.asarray(rng.standard_normal(s).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# Flash attention (XLA custom_vjp): values + grads vs naive
# ---------------------------------------------------------------------------

def _naive_gqa(q, k, v, causal):
    h, hk = q.shape[2], k.shape[2]
    g = h // hk
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    out = ref.attention_reference(q.transpose(0, 2, 1, 3),
                                  kr.transpose(0, 2, 1, 3),
                                  vr.transpose(0, 2, 1, 3), causal=causal)
    return out.transpose(0, 2, 1, 3)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("h,hk", [(4, 2), (4, 4), (6, 1)])
def test_flash_xla_values_and_grads(rng, causal, h, hk):
    b, sq, sk, dh = 2, 96, 96, 16
    q, k, v = _t(rng, b, sq, h, dh), _t(rng, b, sk, hk, dh), _t(rng, b, sk,
                                                                hk, dh)
    o1 = L.flash_attention_xla(q, k, v, causal, block_q=32, block_k=16)
    o2 = _naive_gqa(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-4,
                               atol=3e-5)
    w = jnp.cos(jnp.arange(dh))
    f1 = lambda *a: (L.flash_attention_xla(*a, causal, block_q=32,
                                           block_k=16) * w).sum()
    f2 = lambda *a: (_naive_gqa(*a, causal) * w).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-3,
                                   atol=2e-4)


def test_decode_attention_matches_full(rng):
    """decode_gqa_attention over a cache == last row of full attention."""
    b, s, h, hk, dh = 2, 33, 4, 2, 16
    q_all = _t(rng, b, s, h, dh)
    k_all = _t(rng, b, s, hk, dh)
    v_all = _t(rng, b, s, hk, dh)
    full = _naive_gqa(q_all, k_all, v_all, causal=True)
    got = L.decode_gqa_attention(q_all[:, -1:], k_all, v_all, s)
    np.testing.assert_allclose(np.asarray(got[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=3e-5)


# ---------------------------------------------------------------------------
# SSD (mamba-2): chunked == recurrent; decode step == chunked tail
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_recurrence(rng, chunk):
    b, s, h, p, n = 2, 32, 3, 8, 4
    x = _t(rng, b, s, h, p)
    dt = jax.nn.softplus(_t(rng, b, s, h))
    A = -jnp.exp(_t(rng, h, scale=0.3))
    B = _t(rng, b, s, n)
    C = _t(rng, b, s, n)
    y1, _ = S.ssd_chunked(x, dt, A, B, C, chunk)
    y2 = S.ssd_recurrent_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_continues_chunked_state(rng):
    b, s, h, p, n = 1, 16, 2, 4, 4
    x = _t(rng, b, s + 1, h, p)
    dt = jax.nn.softplus(_t(rng, b, s + 1, h))
    A = -jnp.exp(_t(rng, h, scale=0.3))
    B = _t(rng, b, s + 1, n)
    C = _t(rng, b, s + 1, n)
    # full sequence oracle
    y_all = S.ssd_recurrent_reference(x, dt, A, B, C)
    # chunked over prefix, then one decode step
    _, state = S.ssd_chunked(x[:, :s], dt[:, :s], A, B[:, :s], C[:, :s], 8)
    y_step, _ = S.ssd_decode_step(state, x[:, s], dt[:, s], A, B[:, s],
                                  C[:, s])
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_all[:, s]),
                               rtol=2e-4, atol=2e-4)


def test_ssd_grads_finite(rng):
    b, s, h, p, n = 1, 16, 2, 4, 4
    x = _t(rng, b, s, h, p)
    dt = jax.nn.softplus(_t(rng, b, s, h))
    A = -jnp.exp(_t(rng, h, scale=0.3))
    B, C = _t(rng, b, s, n), _t(rng, b, s, n)
    g = jax.grad(lambda x: S.ssd_chunked(x, dt, A, B, C, 4)[0].sum())(x)
    assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# MoE: dropping dispatch vs dense reference
# ---------------------------------------------------------------------------

def _moe_cfg():
    return get_config("granite-moe-1b-a400m").reduced()


def test_moe_matches_dense_reference_with_full_capacity(rng):
    cfg = _moe_cfg()
    params = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = _t(rng, 2, 16, cfg.d_model)
    y_drop, aux = L.moe(params, cfg, x, capacity_factor=8.0)  # no drops
    y_dense = L.moe_dense_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y_drop), np.asarray(y_dense),
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.5  # load-balance loss ~ O(1)


def test_moe_capacity_drops_tokens(rng):
    cfg = _moe_cfg()
    params = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = _t(rng, 2, 16, cfg.d_model)
    y_tight, _ = L.moe(params, cfg, x, capacity_factor=0.25)
    y_dense = L.moe_dense_reference(params, cfg, x)
    # some tokens dropped -> outputs differ, but remain finite
    assert np.isfinite(np.asarray(y_tight)).all()
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_dense),
                           atol=1e-4)


def test_moe_grads_finite(rng):
    cfg = _moe_cfg()
    params = L.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = _t(rng, 1, 8, cfg.d_model)

    def f(p):
        y, aux = L.moe(p, cfg, x)
        return y.sum() + aux

    g = jax.grad(f)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# Decode consistency: prefill + decode_step == forward at next position
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-moe-1b-a400m",
                                  "mamba2-780m", "hymba-1.5b",
                                  "whisper-base", "llama-3.2-vision-90b",
                                  "qwen1.5-32b"])
def test_decode_matches_forward(rng, arch):
    """Teacher-forcing check: logits from (prefill(t[:s]) ; decode(t[s]))
    must equal logits from a full forward over t[:s+1] at position s."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg,
        # exactness requires no MoE capacity drops and a lossless cache
        capacity_factor=float(max(cfg.n_experts, 1)),
        parallel=dataclasses.replace(cfg.parallel,
                                     kv_cache_dtype="float32"))
    params = T.init_params(cfg, jax.random.key(1))
    B, S = 2, 17
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                         jnp.int32)
    batch = {"tokens": tokens[:, :S]}
    full_batch = {"tokens": tokens}
    if cfg.family == "encdec":
        fr = _t(rng, B, cfg.enc_len, cfg.d_model, scale=0.1)
        batch["frames"] = fr
        full_batch["frames"] = fr
    if cfg.family == "vlm":
        pt = _t(rng, B, cfg.vision_len, cfg.d_model, scale=0.1)
        batch["patches"] = pt
        full_batch["patches"] = pt

    # full forward logits at position S (predicting token S+1)
    x, _, _ = T.forward(cfg, params, full_batch)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    lg_full = L.logits(params["tok"], cfg, x)[:, S]

    # prefill on first S tokens, then decode token S
    _, cache, length = T.prefill(cfg, params, batch)
    spec = T.cache_spec(cfg, B, S + 4)
    cache_p = {}
    for k_, v_ in cache.items():
        tgt = spec[k_].shape
        pads = [(0, t - s_) for s_, t in zip(v_.shape, tgt)]
        cache_p[k_] = jnp.pad(v_.astype(spec[k_].dtype), pads)
    lg_dec, _ = T.decode_step(cfg, params, tokens[:, S:S + 1], cache_p,
                              length)
    np.testing.assert_allclose(np.asarray(lg_dec[:, 0]), np.asarray(lg_full),
                               rtol=5e-3, atol=5e-3)


def test_chunked_xent_matches_direct(rng):
    cfg = get_config("qwen3-1.7b").reduced()
    params = T.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    batch = {"tokens": jnp.asarray(rng.integers(0, 100, (B, S)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, 100, (B, S)), jnp.int32)}
    x, _, _ = T.forward(cfg, params, batch)
    loss_chunked = T._chunked_xent(cfg, params["tok"], x, batch["labels"],
                                   chunk=8)
    lg = L.logits(params["tok"], cfg, x).astype(jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, batch["labels"][..., None], -1)[..., 0]
    loss_direct = (logz - gold).mean()
    np.testing.assert_allclose(float(loss_chunked), float(loss_direct),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# All-arch smoke: loss + grads finite (the assignment's per-arch smoke test)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(rng, arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(cfg, jax.random.key(0))
    B, S = 2, 32
    batch = {"tokens": jnp.zeros((B, S), jnp.int32) + 3,
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.enc_len, cfg.d_model)) * 0.01
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.vision_len, cfg.d_model)) * 0.01
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
    gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                            for x in jax.tree.leaves(g))))
    assert np.isfinite(gn) and gn > 0
    # output shapes + no NaN through prefill/decode as well
    lg, cache, length = T.prefill(cfg, params,
                                  {k: v for k, v in batch.items()
                                   if k != "labels"})
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
