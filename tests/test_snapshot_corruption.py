"""Corrupt-snapshot taxonomy: every damaged store raises
``SnapshotFormatError`` naming the offending path — never a raw
``zipfile``/``KeyError``/``json`` traceback from loader internals.

Each test corrupts a *real* snapshot on disk (truncated npz members,
deleted files, wrong-type MANIFEST fields, invalid JSON) and asserts both
the error type and that the message points at what broke.
"""

import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api
from repro.api import (IndexSpec, PlacementSpec, SnapshotFormatError,
                       SnapshotIntegrityError)
from repro.core import derive_params
from repro.streaming import StreamingDETLSH
from tests.conftest import make_clustered

D = 8


def _truncate(path, keep_bytes=64):
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[: min(keep_bytes, len(data) // 2)])


def _edit_manifest(snap, **fields):
    mpath = os.path.join(snap, "MANIFEST.json")
    manifest = json.load(open(mpath))
    manifest.update(fields)
    with open(mpath, "w") as f:
        json.dump(manifest, f)


def _redigest(snap, fname):
    """Re-record ``fname``'s sha256 so only the *semantic* damage remains."""
    digest = hashlib.sha256(
        open(os.path.join(snap, fname), "rb").read()).hexdigest()
    mpath = os.path.join(snap, "MANIFEST.json")
    manifest = json.load(open(mpath))
    manifest["digests"][fname] = f"sha256:{digest}"
    with open(mpath, "w") as f:
        json.dump(manifest, f)


@pytest.fixture(scope="module")
def static_snap(tmp_path_factory):
    rng = np.random.default_rng(0)
    spec = IndexSpec(kind="static", K=2, L=2, c=1.5, beta_override=0.1,
                     Nr=8, leaf_size=8)
    idx = repro.api.build(jnp.asarray(make_clustered(rng, 128, D)),
                          jax.random.key(0), spec)
    path = str(tmp_path_factory.mktemp("snaps") / "static")
    idx.save(path)
    return path


@pytest.fixture(scope="module")
def streaming_snap(tmp_path_factory):
    rng = np.random.default_rng(1)
    p = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
    idx = StreamingDETLSH.build(jnp.asarray(make_clustered(rng, 96, D)),
                                jax.random.key(0), p, Nr=8, leaf_size=8,
                                delta_capacity=16, max_segments=4)
    idx.upsert(make_clustered(rng, 24, D))    # sealed segment + live delta
    idx.delete(np.arange(5))
    path = str(tmp_path_factory.mktemp("snaps") / "streaming")
    idx.save(path)
    return path


@pytest.fixture(scope="module")
def pdet_snap(tmp_path_factory):
    rng = np.random.default_rng(2)
    spec = IndexSpec(kind="static", K=2, L=2, c=1.5, beta_override=0.1,
                     Nr=8, leaf_size=8,
                     placement=PlacementSpec(
                         mesh_shape=(len(jax.devices()),),
                         mesh_axes=("data",)))
    idx = repro.api.build(jnp.asarray(make_clustered(rng, 128, D)),
                          jax.random.key(0), spec)
    path = str(tmp_path_factory.mktemp("snaps") / "pdet")
    idx.save(path)
    return path


def _copy_snapshot(src, dst):
    os.makedirs(dst)
    for fname in os.listdir(src):
        with open(os.path.join(src, fname), "rb") as fi, \
                open(os.path.join(dst, fname), "wb") as fo:
            fo.write(fi.read())
    return dst


@pytest.fixture
def corruptible(request, tmp_path):
    """A throwaway copy of the named module-scoped snapshot."""
    src = request.getfixturevalue(request.param)
    return _copy_snapshot(src, str(tmp_path / "copy"))


# ---------------------------------------------------------------------------
# Truncated / corrupt npz
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("corruptible,fname", [
    ("static_snap", "arrays.npz"),
    ("streaming_snap", "common.npz"),
    ("streaming_snap", "memtable.npz"),
    ("pdet_snap", "shard_00000.npz"),
], indirect=["corruptible"])
def test_truncated_npz_raises_format_error(corruptible, fname):
    _truncate(os.path.join(corruptible, fname))
    with pytest.raises(SnapshotFormatError, match="truncated or corrupt") \
            as e:
        repro.api.load(corruptible)
    assert fname in str(e.value)                  # names the offending file


@pytest.mark.parametrize("corruptible", ["streaming_snap"], indirect=True)
def test_truncated_segment_npz_raises_format_error(corruptible):
    [seg] = [f for f in os.listdir(corruptible)
             if f.startswith("segment_") and f != "segment_000000.npz"]
    _truncate(os.path.join(corruptible, seg))
    with pytest.raises(SnapshotFormatError, match=seg.replace(".", r"\.")):
        repro.api.load(corruptible)


@pytest.mark.parametrize("corruptible,fname", [
    ("static_snap", "arrays.npz"),
    ("streaming_snap", "memtable.npz"),
    ("pdet_snap", "shard_00000.npz"),
], indirect=["corruptible"])
def test_missing_snapshot_file_raises_format_error(corruptible, fname):
    os.remove(os.path.join(corruptible, fname))
    with pytest.raises(SnapshotFormatError, match="missing") as e:
        repro.api.load(corruptible)
    assert fname in str(e.value)


@pytest.mark.parametrize("corruptible", ["static_snap"], indirect=True)
def test_npz_with_missing_array_raises_format_error(corruptible):
    fpath = os.path.join(corruptible, "arrays.npz")
    with np.load(fpath) as npz:
        arrays = {k: npz[k] for k in npz.files if k != "A"}
    np.savez(fpath, **arrays)
    _redigest(corruptible, "arrays.npz")   # only the missing key remains
    with pytest.raises(SnapshotFormatError, match="'A' is missing"):
        repro.api.load(corruptible)


# ---------------------------------------------------------------------------
# Digest verification (format_version 3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("corruptible,fname", [
    ("static_snap", "arrays.npz"),
    ("streaming_snap", "common.npz"),
    ("streaming_snap", "memtable.npz"),
    ("pdet_snap", "shard_00000.npz"),
], indirect=["corruptible"])
def test_single_bit_flip_raises_integrity_error(corruptible, fname):
    """One flipped bit anywhere in a payload file must be caught by the
    sha256 digest — not slip through as silently wrong arrays."""
    fpath = os.path.join(corruptible, fname)
    blob = bytearray(open(fpath, "rb").read())
    blob[len(blob) // 2] ^= 0x01
    with open(fpath, "wb") as f:
        f.write(bytes(blob))
    with pytest.raises(SnapshotIntegrityError, match="sha256") as e:
        repro.api.load(corruptible)
    assert fname in str(e.value)                  # names the offending file
    assert issubclass(SnapshotIntegrityError, SnapshotFormatError)


@pytest.mark.parametrize("corruptible", ["streaming_snap"], indirect=True)
def test_pre_digest_snapshot_loads_with_warning(corruptible):
    """format_version <= 2 snapshots predate digests: they must keep
    loading (compat), but with a warning nudging a re-save."""
    mpath = os.path.join(corruptible, "MANIFEST.json")
    manifest = json.load(open(mpath))
    del manifest["digests"]
    manifest["format_version"] = 2
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.warns(UserWarning, match="pre-digest"):
        idx = repro.api.load(corruptible)
    assert idx.n_points > 0


@pytest.mark.parametrize("corruptible", ["static_snap"], indirect=True)
def test_v3_without_digests_raises_format_error(corruptible):
    """A version-3 manifest claiming digests but carrying none is damage,
    not compat: refuse it."""
    mpath = os.path.join(corruptible, "MANIFEST.json")
    manifest = json.load(open(mpath))
    del manifest["digests"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(SnapshotFormatError, match="digests"):
        repro.api.load(corruptible)


@pytest.mark.parametrize("corruptible", ["static_snap"], indirect=True)
def test_wrong_type_digests_raises_format_error(corruptible):
    _edit_manifest(corruptible, digests=["not", "a", "dict"])
    with pytest.raises(SnapshotFormatError, match="digests"):
        repro.api.load(corruptible)


# ---------------------------------------------------------------------------
# MANIFEST.json damage
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("corruptible", ["static_snap"], indirect=True)
def test_invalid_json_manifest_raises_format_error(corruptible):
    with open(os.path.join(corruptible, "MANIFEST.json"), "w") as f:
        f.write('{"format": "repro-ann-snapshot", truncated')
    with pytest.raises(SnapshotFormatError, match="not valid JSON"):
        repro.api.load(corruptible)


@pytest.mark.parametrize("corruptible,fields,needle", [
    ("static_snap", {"forest": {"n": "many", "leaf_size": 8}}, "'n'"),
    ("static_snap", {"forest": "not-a-dict"}, "forest"),
    ("static_snap", {"params": "not-a-dict"}, "params"),
    ("static_snap", {"params": {"K": 2}}, "params"),
    ("streaming_snap", {"Nr": "eight"}, "'Nr'"),
    ("streaming_snap", {"id_capacity": True}, "id_capacity"),
    ("streaming_snap", {"segments": {"oops": 1}}, "segments"),
    ("streaming_snap", {"memtable": {"capacity": 16.5, "d": 8,
                                     "count": 0}}, "capacity"),
    ("pdet_snap", {"shards": 3}, "shards"),
    ("pdet_snap", {"placement": [1, 2]}, "placement"),
], indirect=["corruptible"])
def test_wrong_type_manifest_fields_raise_format_error(corruptible, fields,
                                                       needle):
    _edit_manifest(corruptible, **fields)
    with pytest.raises(SnapshotFormatError) as e:
        repro.api.load(corruptible)
    assert needle in str(e.value)
    assert corruptible in str(e.value)            # names the offending path


@pytest.mark.parametrize("corruptible", ["streaming_snap"], indirect=True)
def test_missing_manifest_field_raises_format_error(corruptible):
    mpath = os.path.join(corruptible, "MANIFEST.json")
    manifest = json.load(open(mpath))
    del manifest["next_gid"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(SnapshotFormatError, match="next_gid.*missing"):
        repro.api.load(corruptible)


@pytest.mark.parametrize("corruptible", ["static_snap"], indirect=True)
def test_intact_copy_still_loads(corruptible):
    """The corruption harness itself must not break loading — a byte-true
    copy loads fine (guards against false positives above)."""
    idx = repro.api.load(corruptible)
    assert idx.n_points == 128
