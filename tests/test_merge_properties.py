"""Hypothesis property tests for candidate-set maintenance (Alg. 5's |S|
bookkeeping) — the guarantee-critical invariants:

  * the buffer never holds duplicate real ids,
  * the unique count equals |set(seen real ids)| while under capacity,
  * distances always ascend under top-k selection order,
  * merging is insensitive to the arrival order of candidates.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.query import _merge_candidates


def _merge_rounds(n, cap, rounds):
    ids = jnp.full((cap,), n, jnp.int32)
    d = jnp.full((cap,), jnp.inf)
    seen = set()
    for r_ids in rounds:
        r_ids = np.asarray(r_ids, np.int32)
        r_d = (r_ids * 7 % 23).astype(np.float32)  # deterministic distance
        ids, d, count = _merge_candidates(
            n, ids, d, jnp.asarray(r_ids), jnp.asarray(r_d))
        seen.update(int(x) for x in r_ids if x < n)
    return np.asarray(ids), np.asarray(d), int(count), seen


@settings(max_examples=40, deadline=None)
@given(st.integers(10, 60), st.lists(
    st.lists(st.integers(0, 80), min_size=1, max_size=12),
    min_size=1, max_size=5))
def test_merge_no_duplicates_and_exact_count(n, rounds):
    rounds = [[min(x, n) for x in r] for r in rounds]  # allow sentinel n
    cap = n + 16                                       # over-capacity buffer
    ids, d, count, seen = _merge_rounds(n, cap, rounds)
    real = ids[ids < n]
    assert len(real) == len(set(real.tolist()))        # no duplicates
    assert count == len(seen)                          # exact unique count
    assert set(real.tolist()) == seen                  # nothing lost


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=2, max_size=20),
       st.integers(0, 1000))
def test_merge_order_insensitive(items, seed):
    n, cap = 41, 60
    rng = np.random.default_rng(seed)
    perm = list(items)
    rng.shuffle(perm)
    ids1, d1, c1, _ = _merge_rounds(n, cap, [items])
    ids2, d2, c2, _ = _merge_rounds(n, cap, [perm])
    assert c1 == c2
    assert set(ids1[ids1 < n].tolist()) == set(ids2[ids2 < n].tolist())


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 99), min_size=1, max_size=30))
def test_merge_keeps_best_under_capacity_pressure(items):
    """When uniques exceed capacity, the smallest distances are kept."""
    n, cap = 100, 8
    ids = jnp.full((cap,), n, jnp.int32)
    d = jnp.full((cap,), jnp.inf)
    r_ids = np.asarray(items, np.int32)
    r_d = r_ids.astype(np.float32)          # distance == id
    ids, d, count = _merge_candidates(n, ids, d, jnp.asarray(r_ids),
                                      jnp.asarray(r_d))
    ids, d = np.asarray(ids), np.asarray(d)
    uniq = sorted(set(items))
    expect = uniq[:cap]
    got = sorted(ids[ids < n].tolist())
    assert got == expect
