"""Hypothesis property tests for candidate-set maintenance (Alg. 5's |S|
bookkeeping) — the guarantee-critical invariants:

  * the buffer never holds duplicate real ids,
  * the unique count equals |set(seen real ids)| while under capacity,
  * distances always ascend under top-k selection order,
  * merging is insensitive to the arrival order of candidates,
  * the incremental bitmap+cursor merge (``core.candidates``) is exactly
    equivalent to the seed sort-based merge (``query._merge_candidates``)
    under the engine's invariants (capacity never exceeded before
    termination; duplicate ids carry equal — exact — distances).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import candidates as cand
from repro.core.query import _merge_candidates


def _merge_rounds(n, cap, rounds):
    ids = jnp.full((cap,), n, jnp.int32)
    d = jnp.full((cap,), jnp.inf)
    seen = set()
    for r_ids in rounds:
        r_ids = np.asarray(r_ids, np.int32)
        r_d = (r_ids * 7 % 23).astype(np.float32)  # deterministic distance
        ids, d, count = _merge_candidates(
            n, ids, d, jnp.asarray(r_ids), jnp.asarray(r_d))
        seen.update(int(x) for x in r_ids if x < n)
    return np.asarray(ids), np.asarray(d), int(count), seen


@settings(max_examples=40, deadline=None)
@given(st.integers(10, 60), st.lists(
    st.lists(st.integers(0, 80), min_size=1, max_size=12),
    min_size=1, max_size=5))
def test_merge_no_duplicates_and_exact_count(n, rounds):
    rounds = [[min(x, n) for x in r] for r in rounds]  # allow sentinel n
    cap = n + 16                                       # over-capacity buffer
    ids, d, count, seen = _merge_rounds(n, cap, rounds)
    real = ids[ids < n]
    assert len(real) == len(set(real.tolist()))        # no duplicates
    assert count == len(seen)                          # exact unique count
    assert set(real.tolist()) == seen                  # nothing lost


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 40), min_size=2, max_size=20),
       st.integers(0, 1000))
def test_merge_order_insensitive(items, seed):
    n, cap = 41, 60
    rng = np.random.default_rng(seed)
    perm = list(items)
    rng.shuffle(perm)
    ids1, d1, c1, _ = _merge_rounds(n, cap, [items])
    ids2, d2, c2, _ = _merge_rounds(n, cap, [perm])
    assert c1 == c2
    assert set(ids1[ids1 < n].tolist()) == set(ids2[ids2 < n].tolist())


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 99), min_size=1, max_size=30))
def test_merge_keeps_best_under_capacity_pressure(items):
    """When uniques exceed capacity, the smallest distances are kept."""
    n, cap = 100, 8
    ids = jnp.full((cap,), n, jnp.int32)
    d = jnp.full((cap,), jnp.inf)
    r_ids = np.asarray(items, np.int32)
    r_d = r_ids.astype(np.float32)          # distance == id
    ids, d, count = _merge_candidates(n, ids, d, jnp.asarray(r_ids),
                                      jnp.asarray(r_d))
    ids, d = np.asarray(ids), np.asarray(d)
    uniq = sorted(set(items))
    expect = uniq[:cap]
    got = sorted(ids[ids < n].tolist())
    assert got == expect


# ---------------------------------------------------------------------------
# Incremental (bitmap + cursor) merge == seed sort-based merge
# ---------------------------------------------------------------------------

def _dist_of(ids, n):
    """Id-consistent distances with deliberate cross-id ties (the engine's
    distances are deterministic exact distances, so equal ids always carry
    equal distances; distinct ids may tie)."""
    ids = np.asarray(ids, np.int32)
    return np.where(ids < n, (ids * 7 % 5).astype(np.float32), np.inf)


@settings(max_examples=40, deadline=None)
@given(st.integers(10, 80),
       st.lists(st.lists(st.integers(0, 100), min_size=1, max_size=16),
                min_size=1, max_size=6))
def test_incremental_merge_matches_seed_merge(n, rounds):
    """Identical (ids, dists, unique-count) after every round, in canonical
    (distance, id) order — the seed merge's output order."""
    rounds = [[min(x, n) for x in r] for r in rounds]      # allow sentinel n
    cap = n + 32                                           # capacity invariant
    old_ids = jnp.full((cap,), n, jnp.int32)
    old_d = jnp.full((cap,), jnp.inf)
    state = cand.init_state(n, cap)
    for r_ids in rounds:
        r_ids = np.asarray(r_ids, np.int32)
        r_d = _dist_of(r_ids, n)
        old_ids, old_d, old_count = _merge_candidates(
            n, old_ids, old_d, jnp.asarray(r_ids), jnp.asarray(r_d))
        state = cand.merge_round(n, state, jnp.asarray(r_ids),
                                 jnp.asarray(r_d))
        new_ids, new_d = cand.canonicalize(n, state.ids, state.dists)
        np.testing.assert_array_equal(np.asarray(old_ids),
                                      np.asarray(new_ids))
        np.testing.assert_array_equal(np.asarray(old_d), np.asarray(new_d))
        assert int(old_count) == int(state.count)


@settings(max_examples=30, deadline=None)
@given(st.integers(16, 64),
       st.lists(st.lists(st.integers(0, 120), min_size=1, max_size=12),
                min_size=1, max_size=5))
def test_incremental_merge_bitmap_and_count(n, rounds):
    """The seen-bitmap holds exactly the set of merged real ids and the
    cursor equals the exact unique count."""
    rounds = [[min(x, n) for x in r] for r in rounds]
    state = cand.init_state(n, n + 16)
    seen = set()
    for r_ids in rounds:
        r_ids = np.asarray(r_ids, np.int32)
        state = cand.merge_round(n, state, jnp.asarray(r_ids),
                                 jnp.asarray(_dist_of(r_ids, n)))
        seen.update(int(x) for x in r_ids if x < n)
    assert int(state.count) == len(seen)
    ids = np.asarray(state.ids)
    real = ids[ids < n]
    assert len(real) == len(set(real.tolist()))
    assert set(real.tolist()) == seen
    got_bits = {i for i in range(n)
                if (int(state.seen[i >> 5]) >> (i & 31)) & 1}
    assert got_bits == seen
