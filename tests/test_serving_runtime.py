"""ServingRuntime: epoch pinning, micro-batching, admission control,
metrics (docs/DESIGN.md §9).

Scheduler policy is tested with a fake clock (pure queueing logic, no
jax); the runtime tests drive a real streaming index and check the §9
contracts: mutation barriers, epoch stability across compaction, counted
no-op deletes, gid-exhaustion recovery without losing queued requests,
and the bounded latency ring.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SearchRequest
from repro.core import derive_params
from repro.serving import (Answer, LatencyModel, LatencyRing, MicroBatcher,
                           Rejected, Request, ServingRuntime)
from repro.streaming import StreamingDETLSH
from tests.conftest import brute_force_knn, make_clustered, make_queries_near

D = 16
SAT = dict(r_min=1e6, M=10**6)      # saturating: exact brute-force answers


def _build_index(rng, n=1024, **kw):
    p = derive_params(K=4, c=1.5, L=4, beta_override=0.1)
    kw = {**dict(Nr=32, leaf_size=16, delta_capacity=32, max_segments=3),
          **kw}
    return StreamingDETLSH.build(
        jnp.asarray(make_clustered(rng, n, D)), jax.random.key(0), p, **kw)


# ---------------------------------------------------------------------------
# LatencyRing
# ---------------------------------------------------------------------------

def test_latency_ring_is_bounded_and_list_like():
    ring = LatencyRing(capacity=8)
    assert len(ring) == 0 and np.isnan(ring.percentile(50))
    for v in range(5):
        ring.append(float(v))
    assert len(ring) == 5 and ring.total == 5
    np.testing.assert_array_equal(ring.values(), [0, 1, 2, 3, 4])
    for v in range(5, 20):
        ring.append(float(v))
    # bounded: only the most recent 8 samples retained, oldest first
    assert len(ring) == 8 and ring.total == 20
    np.testing.assert_array_equal(ring.values(), np.arange(12, 20))
    # list-protocol interop the old unbounded list offered
    assert list(ring) == list(np.arange(12.0, 20.0))
    assert float(np.percentile(ring, 50)) == ring.percentile(50)
    assert ring.percentile(0) == 12.0 and ring.percentile(100) == 19.0


def test_service_stats_ring_keeps_percentile_api(rng):
    """Satellite regression: ServiceStats.latencies_ms is now a bounded
    ring but percentile()/summary() behave exactly as before."""
    from repro.serving.lsh_service import ServiceStats
    stats = ServiceStats()
    assert len(stats.latencies_ms) == 0
    assert np.isnan(stats.percentile(50))
    for v in range(10):
        stats.latencies_ms.append(float(v))
    assert stats.percentile(50) == 4.5
    s = stats.summary()
    assert set(s) == {"queries", "batches", "pad_queries", "upserts",
                      "deletes", "compactions", "p50_ms", "p99_ms"}
    assert stats.latencies_ms.capacity == 4096       # O(1) memory forever


# ---------------------------------------------------------------------------
# Scheduler (fake clock — no jax)
# ---------------------------------------------------------------------------

def _req(rid, arrival, deadline=None):
    return Request(rid=rid, query=np.zeros(D, np.float32), arrival=arrival,
                   deadline=deadline)


def test_batcher_flushes_on_full_and_max_wait():
    mb = MicroBatcher(max_batch=4, pad_to=4, max_wait=0.010)
    assert not mb.ready(now=0.0)
    for i in range(3):
        assert mb.enqueue(_req(i, arrival=0.0)) is None
    assert not mb.ready(now=0.005)          # partial, under max_wait
    assert mb.ready(now=0.011)              # oldest waited past max_wait
    mb.enqueue(_req(3, arrival=0.001))
    assert mb.ready(now=0.002)              # full batch flushes immediately
    batch, degraded, shed = mb.next_batch(now=0.002)
    assert [r.rid for r in batch] == [0, 1, 2, 3]
    assert not degraded and not shed


def test_batcher_queue_cap_rejects_explicitly():
    mb = MicroBatcher(max_batch=4, pad_to=4, queue_cap=2)
    assert mb.enqueue(_req(0, 0.0)) is None
    assert mb.enqueue(_req(1, 0.0)) is None
    rej = mb.enqueue(_req(2, 0.0))
    assert isinstance(rej, Rejected) and rej.reason == "queue_full"
    assert rej.rid == 2 and len(mb) == 2    # never silently grows


def test_batcher_flushes_under_deadline_pressure():
    model = LatencyModel()
    model.observe(4, False, 0.050)          # batches take ~50ms
    mb = MicroBatcher(max_batch=4, pad_to=4, max_wait=10.0,
                      latency_model=model)
    mb.enqueue(_req(0, arrival=0.0, deadline=0.200))
    assert not mb.ready(now=0.010)          # 190ms margin >> 50ms predicted
    assert mb.ready(now=0.160)              # waiting longer would miss it


def test_batcher_sheds_unmeetable_deadlines():
    model = LatencyModel()
    model.observe(4, False, 0.050)
    model.observe(4, True, 0.050)           # degrading would not help
    mb = MicroBatcher(max_batch=4, pad_to=4, latency_model=model)
    mb.enqueue(_req(0, arrival=0.0, deadline=0.010))   # unmeetable
    mb.enqueue(_req(1, arrival=0.0, deadline=10.0))    # fine
    mb.enqueue(_req(2, arrival=0.0))                   # no deadline
    batch, degraded, shed = mb.next_batch(now=0.0)
    assert [r.rid for r in batch] == [1, 2]
    assert [s.rid for s in shed] == [0]
    assert shed[0].reason == "deadline" and not degraded


def test_batcher_degrades_before_shedding():
    model = LatencyModel()
    model.observe(4, False, 0.100)          # full effort would miss
    model.observe(4, True, 0.010)           # capped effort meets it
    mb = MicroBatcher(max_batch=4, pad_to=4, latency_model=model)
    mb.enqueue(_req(0, arrival=0.0, deadline=0.050))
    batch, degraded, shed = mb.next_batch(now=0.0)
    assert [r.rid for r in batch] == [0]
    assert degraded and not shed            # degrade strictly before shed


def test_batcher_cold_model_admits_everything():
    mb = MicroBatcher(max_batch=4, pad_to=4)
    mb.enqueue(_req(0, arrival=0.0, deadline=0.001))
    batch, degraded, shed = mb.next_batch(now=0.0)
    assert len(batch) == 1 and not shed     # no measurement -> no shedding


# ---------------------------------------------------------------------------
# Runtime over a live index
# ---------------------------------------------------------------------------

@pytest.mark.timeout(300)
def test_runtime_serves_exact_answers_and_counts(rng):
    idx = _build_index(rng)
    data, _ = idx.pin_state().survivors()
    # max_wait pinned high: batches flush on size only, so the grouping
    # (8 + 8 + 4) is deterministic regardless of wall-clock jitter
    rt = ServingRuntime(idx, k=5, max_batch=8, pad_to=8, max_wait_ms=1e6,
                        request=SearchRequest(k=5, **SAT))
    queries = make_queries_near(data, rng, 20)
    out = rt.serve([(time.perf_counter(), q) for q in queries])
    assert len(out) == 20 and all(isinstance(o, Answer) for o in out)
    gt_i, gt_d = brute_force_knn(data, queries, 5)
    for i, ans in enumerate(out):
        assert set(ans.ids.tolist()) == set(gt_i[i].tolist())
        np.testing.assert_allclose(ans.dists, gt_d[i], rtol=1e-4, atol=1e-4)
    s = rt.stats.summary()
    assert s["queries"] == 20 and s["batches"] == 3
    assert s["pad_queries"] == 4 and s["shed_total"] == 0
    assert s["epochs_pinned"] == 3 and len(rt.stats.latencies) == 20
    assert s["p99_ms"] >= s["p50_ms"] > 0
    assert idx.manifest.pinned_versions() == ()     # all epochs drained


@pytest.mark.timeout(300)
def test_pinned_epoch_survives_concurrent_compaction(rng):
    """Satellite: compaction triggered concurrently with an in-flight
    pinned epoch does not invalidate that epoch's answers."""
    idx = _build_index(rng, n=512, max_segments=10)
    rt = ServingRuntime(idx, k=5, request=SearchRequest(k=5, **SAT))
    rt.upsert(make_clustered(rng, 100, D))          # sealed segments +
    rt.delete(np.arange(0, 30))                     # tombstones to merge
    queries = jnp.asarray(make_clustered(rng, 4, D))

    epoch = rt.pin()
    assert idx.manifest.pinned_versions() != ()
    before = epoch.search(queries, SearchRequest(k=5, n_active=4, **SAT))
    assert rt.compact()                             # swap under the reader
    after = epoch.search(queries, SearchRequest(k=5, n_active=4, **SAT))
    np.testing.assert_array_equal(np.asarray(before.ids),
                                  np.asarray(after.ids))
    np.testing.assert_array_equal(np.asarray(before.dists),
                                  np.asarray(after.dists))
    rt.release(epoch)
    assert idx.manifest.pinned_versions() == ()     # retired on drain
    assert rt.stats.epochs_retired == 1


@pytest.mark.timeout(300)
def test_mutations_are_barriers_and_noops_counted(rng):
    idx = _build_index(rng, n=256)
    rt = ServingRuntime(idx, k=3, max_batch=8, pad_to=8,
                        request=SearchRequest(k=3, **SAT))
    probe = np.asarray(idx.pin_state().survivors()[0][0] + 40.0, np.float32)
    [gid] = rt.upsert(probe)
    rid = rt.submit(probe)
    # the delete flushes the queued query first (mutation barrier): the
    # queued request answers on pre-delete state, in submission order
    rt.delete([gid])
    assert int(rt.outcomes[rid].ids[0]) == int(gid)
    rid2 = rt.submit(probe)
    rt.flush()
    assert int(rt.outcomes[rid2].ids[0]) != int(gid)
    # never-inserted gids: counted no-op, not an error
    removed = rt.delete([10 ** 6, 10 ** 6 + 1])
    assert removed == 0 and rt.stats.noop_deletes == 2
    assert rt.stats.deletes == 1


@pytest.mark.timeout(300)
def test_gid_exhaustion_recovers_without_losing_queued_requests(rng):
    """Satellite: gid-space exhaustion mid-serve raises after the barrier
    flush and before any index mutation — queued requests all answer, and
    grow_id_capacity + resubmit completes the upsert."""
    idx = _build_index(rng, n=128, id_capacity=140)
    rt = ServingRuntime(idx, k=3, request=SearchRequest(k=3, **SAT))
    queries = make_clustered(rng, 5, D)
    rids = [rt.submit(q) for q in queries]
    big = make_clustered(rng, 64, D)                # would pass id_capacity
    with pytest.raises(ValueError, match="gid space exhausted"):
        rt.upsert(big)
    # every queued request was flushed and answered before the failure
    assert all(isinstance(rt.outcomes[r], Answer) for r in rids)
    assert rt.stats.shed_total == 0
    n_before = idx.n_live
    idx.grow_id_capacity(4096)
    assert len(rt.upsert(big)) == 64                # recovery completes
    assert idx.n_live == n_before + 64
    out = rt.serve([(time.perf_counter(), q) for q in queries])
    assert all(isinstance(o, Answer) for o in out)  # still serving


@pytest.mark.timeout(300)
def test_runtime_sheds_on_queue_cap_and_records_outcome(rng):
    idx = _build_index(rng, n=256)
    rt = ServingRuntime(idx, k=3, max_batch=4, pad_to=4, queue_cap=2,
                        request=SearchRequest(k=3, **SAT))
    queries = make_clustered(rng, 4, D)
    rids = [rt.submit(q) for q in queries]
    rejected = [r for r in rids if isinstance(rt.outcomes.get(r), Rejected)]
    assert len(rejected) == 2                       # cap=2: last two shed
    assert all(rt.outcomes[r].reason == "queue_full" for r in rejected)
    rt.flush()
    assert rt.stats.shed["queue_full"] == 2
    assert all(isinstance(rt.outcomes[r], Answer)
               for r in rids if r not in rejected)


@pytest.mark.timeout(300)
def test_runtime_degrades_under_deadline_pressure(rng):
    """An unmeetable deadline at full effort but meetable degraded serves
    degraded (capped max_rounds), recording degraded=True — before ever
    shedding."""
    idx = _build_index(rng, n=256)
    rt = ServingRuntime(idx, k=3, max_batch=4, pad_to=4,
                        degraded_max_rounds=1,
                        request=SearchRequest(k=3, **SAT))
    # force the model: full effort 100ms, degraded 1ms
    rt.batcher.model.observe(4, False, 0.100)
    rt.batcher.model.observe(4, True, 0.001)
    now = time.perf_counter()
    rid = rt.submit(idx.pin_state().survivors()[0][0], deadline=now + 0.050)
    rt.flush()
    ans = rt.outcomes[rid]
    assert isinstance(ans, Answer) and ans.degraded
    assert rt.stats.degraded_batches == 1 and rt.stats.shed_total == 0
