"""Property test: epoch-pinned serving equals serialized execution.

For random interleavings of {query, upsert, delete, compact} driven
through the ``ServingRuntime`` (hypothesis; deterministic shim fallback),
every query's answer must be bit-identical to a from-scratch *static*
build over its pinned epoch's surviving union — the serialized-oracle
equivalence of docs/DESIGN.md §9.  Saturating requests (every leaf
admitted, exact rerank) make the answer the exact brute-force top-k, so
"identical to a fresh static build" and "identical to brute force over the
pinned survivors" coincide and the check is deterministic.

Checked on both engines; a separate fixed interleaving drives the same
oracle against a PDET-sharded from-scratch build (mesh of all host
devices — 1 in tier-1, 4 in the multidevice CI job).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.api
from repro.api import IndexSpec, PlacementSpec, SearchRequest
from repro.core import derive_params
from repro.serving import Answer, ServingRuntime
from repro.streaming import StreamingDETLSH

D = 8
K_NN = 4
SAT = dict(r_min=1e6, M=10**6)
PARAMS = derive_params(K=2, c=1.5, L=2, beta_override=0.1)
# One fixed geometry => one compile per (engine, shape) across examples.
KW = dict(Nr=8, leaf_size=8, delta_capacity=16, max_segments=2)


def _oracle(view, queries, k):
    """Brute-force top-k over the pinned epoch's surviving union."""
    vecs, gids = view.survivors()
    d2 = ((queries[:, None, :] - vecs[None, :, :]) ** 2).sum(-1)
    sel = np.argsort(d2, axis=1, kind="stable")[:, :k]
    return gids[sel], np.sqrt(np.take_along_axis(d2, sel, axis=1))


def _check_epoch_answers(res, view, queries, k, tag):
    gt_gids, gt_d = _oracle(view, queries, k)
    ids = np.asarray(res.ids)[:, :k]
    np.testing.assert_allclose(np.asarray(res.dists)[:, :k], gt_d,
                               rtol=1e-4, atol=1e-4, err_msg=str(tag))
    for b in range(len(queries)):          # same ids up to distance ties
        assert set(ids[b].tolist()) == set(gt_gids[b].tolist()), (tag, b)


@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.lists(st.tuples(st.sampled_from(["query", "upsert", "delete",
                                           "compact"]),
                          st.integers(min_value=1, max_value=16)),
                min_size=3, max_size=7))
@pytest.mark.timeout(600)
def test_interleavings_answer_on_their_pinned_epoch(seed, ops):
    rng = np.random.default_rng(seed)
    data = rng.standard_normal((48, D)).astype(np.float32)
    idx = StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0),
                                PARAMS, **KW)
    rt = ServingRuntime(idx, k=K_NN, max_batch=4, pad_to=4,
                        request=SearchRequest(k=K_NN, **SAT))
    queries = rng.standard_normal((3, D)).astype(np.float32)
    sat_req = SearchRequest(k=K_NN, n_active=3, **SAT)

    held = []                              # epochs pinned across later ops
    for kind, arg in ops:
        if kind == "query":
            # pin an epoch, query it now, and HOLD it: it must answer the
            # same after every later mutation/compaction in the sequence
            epoch = rt.pin()
            res = epoch.search(jnp.asarray(queries), sat_req)
            _check_epoch_answers(res, epoch.view, queries, K_NN, "live")
            held.append((epoch, np.asarray(res.ids), np.asarray(res.dists)))
        elif kind == "upsert":
            rt.upsert(rng.standard_normal((arg, D)).astype(np.float32))
        elif kind == "delete":
            alive = sorted(idx.locator.keys())
            if alive:
                rt.delete(rng.choice(alive, size=min(arg, len(alive)),
                                     replace=False))
        elif kind == "compact":
            rt.compact()

    # also serve through the micro-batch path on the final state
    final = rt.pin()
    out = rt.serve([(0.0, q) for q in queries])
    assert all(isinstance(o, Answer) for o in out)
    ids = np.stack([o.ids for o in out])
    dists = np.stack([o.dists for o in out])
    gt_gids, gt_d = _oracle(final.view, queries, K_NN)
    np.testing.assert_allclose(dists[:, :K_NN], gt_d, rtol=1e-4, atol=1e-4)
    for b in range(len(queries)):
        assert set(ids[b, :K_NN].tolist()) == set(gt_gids[b].tolist())
    rt.release(final)

    # every held epoch: bit-identical replay after the full interleaving,
    # on both engines, and equal to a from-scratch static build of its
    # pinned surviving union
    for epoch, ids0, dists0 in held:
        for engine in ("fused", "vmap"):
            req = SearchRequest(k=K_NN, n_active=3, engine=engine, **SAT)
            res = epoch.search(jnp.asarray(queries), req)
            _check_epoch_answers(res, epoch.view, queries, K_NN, engine)
        replay = epoch.search(jnp.asarray(queries), sat_req)
        np.testing.assert_array_equal(np.asarray(replay.ids), ids0)
        np.testing.assert_array_equal(np.asarray(replay.dists), dists0)

        vecs, gids = epoch.view.survivors()
        if len(gids) >= K_NN:              # static build needs >= k rows
            static = repro.api.build(
                jnp.asarray(vecs), jax.random.key(1),
                IndexSpec(kind="static", K=2, L=2, c=1.5, beta_override=0.1,
                          Nr=8, leaf_size=8))
            sres = static.search(jnp.asarray(queries), sat_req)
            sids = gids[np.asarray(sres.ids)[:, :K_NN]]
            np.testing.assert_allclose(
                np.asarray(sres.dists)[:, :K_NN],
                np.asarray(replay.dists)[:, :K_NN], rtol=1e-4, atol=1e-4)
            for b in range(len(queries)):
                assert set(sids[b].tolist()) == \
                    set(np.asarray(replay.ids)[b, :K_NN].tolist())
        rt.release(epoch)
    assert idx.manifest.pinned_versions() == ()


@pytest.mark.timeout(600)
def test_pinned_epoch_matches_pdet_sharded_rebuild(rng):
    """One fixed interleaving, same oracle, against a PDET-sharded
    from-scratch build of the pinned epoch's survivors (the sharded leg of
    the §9 equivalence — mesh over all host devices)."""
    data = rng.standard_normal((96, D)).astype(np.float32)
    idx = StreamingDETLSH.build(jnp.asarray(data), jax.random.key(0),
                                PARAMS, **KW)
    rt = ServingRuntime(idx, k=K_NN, request=SearchRequest(k=K_NN, **SAT))
    rt.upsert(rng.standard_normal((20, D)).astype(np.float32))
    rt.delete(np.arange(0, 30))
    epoch = rt.pin()
    rt.upsert(rng.standard_normal((10, D)).astype(np.float32))
    rt.compact()

    queries = rng.standard_normal((4, D)).astype(np.float32)
    res = epoch.search(jnp.asarray(queries),
                       SearchRequest(k=K_NN, n_active=4, **SAT))
    vecs, gids = epoch.view.survivors()
    placement = PlacementSpec(mesh_shape=(len(jax.devices()),),
                              mesh_axes=("data",))
    pdet = repro.api.build(
        jnp.asarray(vecs), jax.random.key(1),
        IndexSpec(kind="static", K=2, L=2, c=1.5, beta_override=0.1,
                  Nr=8, leaf_size=8, placement=placement))
    pres = pdet.search(jnp.asarray(queries),
                       SearchRequest(k=K_NN, n_active=4, **SAT))
    pids = gids[np.asarray(pres.ids)[:, :K_NN]]
    np.testing.assert_allclose(np.asarray(pres.dists)[:, :K_NN],
                               np.asarray(res.dists)[:, :K_NN],
                               rtol=1e-4, atol=1e-4)
    for b in range(len(queries)):
        assert set(pids[b].tolist()) == \
            set(np.asarray(res.ids)[b, :K_NN].tolist())
    rt.release(epoch)
